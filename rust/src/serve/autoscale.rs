//! Elastic fleet autoscaling: a deterministic controller that wakes and
//! drains servers from queue-depth / SLO-attainment signals.
//!
//! The paper's 3.12× speedup and Δ_max ≤ 1.5 % guarantee only pay off at
//! the fleet level if capacity tracks load. This module closes that loop
//! the way Environment-Aware Dynamic Pruning (O'Quinn et al., 2025)
//! adapts compression to runtime conditions — except the adaptation knob
//! here is the number of *awake servers*, and every scale decision is
//! priced against real activation cost in the spirit of HALP's
//! hardware-aware latency accounting (Shen et al., 2021): waking a server
//! streams its initial-residency engine weights over DRAM bandwidth plus
//! a fixed init overhead ([`crate::hwsim::Device::swap_in_ms`] — the same
//! pricing as a cold hot-swap), and the wake window is charged energy
//! E = P·L against the summary.
//!
//! ## Control plane
//!
//! The event loop ([`crate::serve::simulate_fleet`]) fires a `Control`
//! event every [`AutoscaleConfig::interval_ms`] of virtual time for the
//! duration of the offered trace. Each tick builds the same
//! [`FleetView`] snapshot the router sees, folds the window's outcomes
//! into EWMA signals ([`SignalTracker`] → [`ScaleSignals`]), and asks the
//! configured [`AutoscalePolicy`] for a [`ScaleDecision`]. The loop —
//! not the policy — enforces the `min_active..=max_active` bounds,
//! picks the wake target (lowest-index asleep server) and the drain
//! target (idlest active server), and executes the decision as
//! `ScaleUp`/`WakeDone`/`DrainStart`/`ScaleDown` events with the same
//! hard-error discipline as hot-swaps: routing to an asleep or draining
//! server is structurally impossible, and a scale event that finds its
//! server in the wrong lifecycle state is an internal invariant
//! violation that errors out.
//!
//! ## Lifecycle
//!
//! ```text
//!          ScaleUp ... WakeDone            DrainStart
//!  Asleep ────────────────────▶ Active ───────────────▶ Draining
//!    ▲                                                     │
//!    └──────────────── ScaleDown (queue drained) ──────────┘
//! ```
//!
//! A draining server takes no new work but finishes everything already
//! queued (batch timeouts are bypassed — it dispatches as fast as the
//! device allows), then sleeps. A waking server is asleep until its
//! `WakeDone` fires; it resumes with its *initial* resident set (that is
//! exactly what the wake cost streamed).
//!
//! Everything here is deterministic: the signals are pure functions of
//! the event stream, the policies are pure state machines over the
//! signals, and tie-breaks are by server index — so autoscaled runs
//! reproduce byte-identically, and `ScalePolicy::Off` leaves the event
//! stream (and therefore the summary) bit-exact with the fixed-fleet
//! simulator.

use super::predict::{ForecastObs, PredictivePolicy};
use super::router::FleetView;

/// Where a server is in its serving lifecycle. With autoscaling off every
/// server is permanently [`Lifecycle::Active`] — the fixed-fleet
/// behavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lifecycle {
    /// Routable and serving.
    Active,
    /// Finishing its queued work; takes no new requests; sleeps when the
    /// queue empties.
    Draining,
    /// Powered down for serving purposes. Waking it costs
    /// initial-residency weight streaming + init (and E = P·L of energy).
    Asleep,
}

/// EWMA smoothing factor for the control signals: one control interval
/// carries half the weight of all history before it.
pub const EWMA_ALPHA: f64 = 0.5;

/// Queue-depth policy: queued requests per active server above which the
/// fleet counts as pressured (scale-up side).
pub const QUEUE_HIGH_WATER: f64 = 8.0;

/// Queue-depth policy: queued requests per active server below which the
/// fleet counts as over-provisioned (scale-down side).
pub const QUEUE_LOW_WATER: f64 = 1.0;

/// Consecutive control ticks a queue-depth signal must persist before a
/// decision fires — the anti-thrash hysteresis (both directions).
pub const SCALE_CONSECUTIVE: u32 = 2;

/// Attainment policy: EWMA SLO attainment below this triggers the
/// scale-up side of the band.
pub const ATTAIN_LOW: f64 = 0.92;

/// Attainment policy: EWMA SLO attainment above this triggers the
/// scale-down side of the band.
pub const ATTAIN_HIGH: f64 = 0.99;

/// Consecutive ticks below [`ATTAIN_LOW`] before an attainment scale-up.
pub const ATTAIN_UP_TICKS: u32 = 2;

/// Consecutive ticks above [`ATTAIN_HIGH`] before an attainment
/// scale-down — deliberately slower than the up side: releasing capacity
/// is cheap to defer, missing SLOs is not.
pub const ATTAIN_DOWN_TICKS: u32 = 6;

/// Autoscaling parameters ([`crate::serve::ServeConfig::autoscale`]).
/// [`AutoscaleConfig::off`] (the default) disables the control plane
/// entirely: no `Control` events are scheduled and the simulation is
/// byte-identical to the fixed-fleet simulator, whatever the other knobs
/// say.
#[derive(Clone, Debug)]
pub struct AutoscaleConfig {
    /// Which controller drives scale decisions (`Off` = fixed fleet).
    pub policy: ScalePolicy,
    /// Control interval, virtual ms (CLI `--scale-interval-ms`).
    pub interval_ms: f64,
    /// Lower bound on active servers; also how many servers start awake
    /// (CLI `--min-servers`).
    pub min_active: usize,
    /// Upper bound on awake-or-waking servers, clamped to the fleet size
    /// (CLI `--max-servers`; `usize::MAX` = the whole fleet).
    pub max_active: usize,
    /// Queue-depth high-water mark override (CLI `--scale-high-water`;
    /// default [`QUEUE_HIGH_WATER`]). Only the queue-depth policy reads it.
    pub queue_high: f64,
    /// Queue-depth low-water mark override (CLI `--scale-low-water`;
    /// default [`QUEUE_LOW_WATER`]).
    pub queue_low: f64,
}

impl AutoscaleConfig {
    /// The fixed-fleet configuration: no controller, knobs inert.
    pub fn off() -> AutoscaleConfig {
        AutoscaleConfig {
            policy: ScalePolicy::Off,
            interval_ms: 100.0,
            min_active: 1,
            max_active: usize::MAX,
            queue_high: QUEUE_HIGH_WATER,
            queue_low: QUEUE_LOW_WATER,
        }
    }

    /// Is the control plane on at all?
    pub fn enabled(&self) -> bool {
        self.policy != ScalePolicy::Off
    }
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig::off()
    }
}

/// Autoscaling policy names — the CLI registry, mirroring
/// [`super::router::Policy`]: [`ScalePolicy::build`] yields the actual
/// [`AutoscalePolicy`] implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScalePolicy {
    /// Fixed fleet: no control plane (the default).
    Off,
    /// Scale on queued-requests-per-active-server watermarks with
    /// consecutive-tick hysteresis ([`QueueDepthPolicy`]).
    QueueDepth,
    /// Scale to hold EWMA SLO attainment inside a target band
    /// ([`AttainmentPolicy`]).
    Attainment,
    /// Forecast-driven pre-wake/early-sleep controller
    /// ([`PredictivePolicy`]): compares the forecast arrival rate at the
    /// wake-latency horizon against active capacity, degrading to the
    /// reactive queue-depth controller when forecast confidence is low.
    Predictive,
}

impl ScalePolicy {
    /// Canonical CLI names, in enum order — the single source of truth
    /// shared by [`ScalePolicy::parse`], [`ScalePolicy::name`] and the
    /// `main.rs` "valid: …" error strings.
    pub const NAMES: [&'static str; 4] = ["off", "queue-depth", "attainment", "predictive"];

    /// Every policy (sweeps and property tests).
    pub const ALL: [ScalePolicy; 4] = [
        ScalePolicy::Off,
        ScalePolicy::QueueDepth,
        ScalePolicy::Attainment,
        ScalePolicy::Predictive,
    ];

    /// Parse a CLI name.
    pub fn parse(name: &str) -> Option<ScalePolicy> {
        match name {
            "off" => Some(ScalePolicy::Off),
            "queue-depth" | "qd" => Some(ScalePolicy::QueueDepth),
            "attainment" | "at" => Some(ScalePolicy::Attainment),
            "predictive" | "pred" => Some(ScalePolicy::Predictive),
            _ => None,
        }
    }

    /// Canonical name of this policy.
    pub fn name(&self) -> &'static str {
        match self {
            ScalePolicy::Off => ScalePolicy::NAMES[0],
            ScalePolicy::QueueDepth => ScalePolicy::NAMES[1],
            ScalePolicy::Attainment => ScalePolicy::NAMES[2],
            ScalePolicy::Predictive => ScalePolicy::NAMES[3],
        }
    }

    /// Build the policy implementation (`None` for `Off`).
    pub fn build(&self, cfg: &AutoscaleConfig) -> Option<Box<dyn AutoscalePolicy>> {
        match self {
            ScalePolicy::Off => None,
            ScalePolicy::QueueDepth => Some(Box::new(QueueDepthPolicy::new(
                cfg.queue_high,
                cfg.queue_low,
                SCALE_CONSECUTIVE,
            ))),
            ScalePolicy::Attainment => Some(Box::new(AttainmentPolicy::new(
                ATTAIN_LOW,
                ATTAIN_HIGH,
                ATTAIN_UP_TICKS,
                ATTAIN_DOWN_TICKS,
            ))),
            ScalePolicy::Predictive => Some(Box::new(PredictivePolicy::new(
                QueueDepthPolicy::new(cfg.queue_high, cfg.queue_low, SCALE_CONSECUTIVE),
            ))),
        }
    }
}

/// One control tick's smoothed view of fleet health — what a policy
/// decides from, alongside the raw [`FleetView`].
#[derive(Clone, Copy, Debug)]
pub struct ScaleSignals {
    /// Virtual time of the tick.
    pub now_ms: f64,
    /// Servers currently [`Lifecycle::Active`].
    pub active: usize,
    /// Asleep servers with a wake in flight (capacity already committed).
    pub waking: usize,
    /// Servers currently [`Lifecycle::Draining`].
    pub draining: usize,
    /// Servers currently [`Lifecycle::Asleep`] (wake-eligible ones).
    pub asleep: usize,
    /// Instantaneous queued requests across active servers, per active
    /// server.
    pub queue_per_active: f64,
    /// EWMA of [`ScaleSignals::queue_per_active`] ([`EWMA_ALPHA`]).
    pub queue_ewma: f64,
    /// SLO attainment over this control window's outcomes (completed
    /// within SLO / all requests that reached an outcome; 1.0 for an idle
    /// window — no traffic is not an SLO miss).
    pub window_attainment: f64,
    /// EWMA of [`ScaleSignals::window_attainment`].
    pub attainment_ewma: f64,
}

/// What a policy wants done this tick. The event loop clamps the
/// decision to the `min_active..=max_active` bounds and picks the
/// concrete server (lowest-index asleep to wake, idlest active to
/// drain); a decision that cannot be applied is dropped, not queued.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScaleDecision {
    /// No change.
    Hold,
    /// Wake one asleep server. `since_ms` is when the triggering pressure
    /// episode began — the reaction-time clock starts there, so the
    /// summary's `mean_reaction_ms` covers detection hysteresis *and* the
    /// wake itself.
    Up { since_ms: f64 },
    /// Drain the idlest active server (it sleeps once its queue empties).
    Down,
}

/// An open-ended autoscaling controller, decided once per control tick
/// over the live [`FleetView`] snapshot and the EWMA [`ScaleSignals`].
/// Implementations must be deterministic state machines: same tick
/// sequence, same decisions.
pub trait AutoscalePolicy {
    /// Canonical policy name (summary + CLI).
    fn name(&self) -> &'static str;

    /// Decide this tick. The event loop applies bounds and target
    /// selection; returning `Up`/`Down` when no capacity change is
    /// possible is allowed (the decision is dropped).
    fn decide(&mut self, view: &FleetView, sig: &ScaleSignals) -> ScaleDecision;

    /// Forecast delivery, called by the event loop immediately before
    /// [`AutoscalePolicy::decide`] on ticks where a forecaster is active
    /// (`--autoscale predictive`). Reactive policies ignore it — the
    /// default is a no-op — which is also how [`PredictivePolicy`]
    /// degrades when no forecast arrives at all.
    fn observe_forecast(&mut self, _obs: &ForecastObs) {}

    /// Cumulative forecast-initiated wake decisions (pre-wakes) this
    /// policy has issued — the summary's `prewakes` counter. The event
    /// loop may still drop an issued decision at the `max_active` bound.
    fn prewakes(&self) -> u64 {
        0
    }
}

/// Folds per-window outcome counts into the EWMA control signals. Owned
/// by the event loop; [`SignalTracker::tick`] is called exactly once per
/// control tick with *cumulative* counters (it keeps the last snapshot
/// and differences internally).
#[derive(Clone, Debug)]
pub struct SignalTracker {
    last_outcomes: u64,
    last_attained: u64,
    queue_ewma: f64,
    attain_ewma: f64,
}

impl SignalTracker {
    /// A fresh tracker: attainment optimistic (1.0), queues empty.
    pub fn new() -> SignalTracker {
        SignalTracker {
            last_outcomes: 0,
            last_attained: 0,
            queue_ewma: 0.0,
            attain_ewma: 1.0,
        }
    }

    /// Advance one control window. `outcomes` / `attained` are cumulative
    /// (completed + rejected + expired, and completed-within-SLO);
    /// `queued_active` is the instantaneous queued total across active
    /// servers; the lifecycle counts describe the fleet right now.
    #[allow(clippy::too_many_arguments)]
    pub fn tick(
        &mut self,
        now_ms: f64,
        outcomes: u64,
        attained: u64,
        queued_active: usize,
        active: usize,
        waking: usize,
        draining: usize,
        asleep: usize,
    ) -> ScaleSignals {
        let d_out = outcomes - self.last_outcomes;
        let d_att = attained - self.last_attained;
        self.last_outcomes = outcomes;
        self.last_attained = attained;
        // an idle window is neutral, not a miss: hold the signal at 1.0
        let window_attainment = if d_out == 0 { 1.0 } else { d_att as f64 / d_out as f64 };
        let queue_per_active = queued_active as f64 / active.max(1) as f64;
        self.queue_ewma = EWMA_ALPHA * queue_per_active + (1.0 - EWMA_ALPHA) * self.queue_ewma;
        self.attain_ewma =
            EWMA_ALPHA * window_attainment + (1.0 - EWMA_ALPHA) * self.attain_ewma;
        ScaleSignals {
            now_ms,
            active,
            waking,
            draining,
            asleep,
            queue_per_active,
            queue_ewma: self.queue_ewma,
            window_attainment,
            attainment_ewma: self.attain_ewma,
        }
    }
}

impl Default for SignalTracker {
    fn default() -> Self {
        SignalTracker::new()
    }
}

/// Queue-depth controller: scale up when the EWMA backlog per active
/// server has exceeded the high-water mark for [`SCALE_CONSECUTIVE`]
/// consecutive ticks; drain the idlest server once it has sat below the
/// low-water mark just as long. The dead band between the marks is the
/// hysteresis that keeps a borderline fleet from thrashing.
pub struct QueueDepthPolicy {
    high: f64,
    low: f64,
    need: u32,
    above: u32,
    below: u32,
    /// When the current pressure episode began (NaN = none) — the
    /// reaction-time anchor reported through [`ScaleDecision::Up`].
    episode_ms: f64,
}

impl QueueDepthPolicy {
    /// Controller with explicit watermarks (`high > low >= 0`) and the
    /// consecutive-tick requirement (`need >= 1`). The CLI path goes
    /// through [`ScalePolicy::build`], which validates via
    /// [`crate::serve::simulate_fleet`]'s config checks.
    pub fn new(high: f64, low: f64, need: u32) -> QueueDepthPolicy {
        QueueDepthPolicy {
            high,
            low,
            need: need.max(1),
            above: 0,
            below: 0,
            episode_ms: f64::NAN,
        }
    }
}

impl AutoscalePolicy for QueueDepthPolicy {
    fn name(&self) -> &'static str {
        ScalePolicy::NAMES[1]
    }

    fn decide(&mut self, _view: &FleetView, sig: &ScaleSignals) -> ScaleDecision {
        if sig.queue_ewma > self.high {
            self.below = 0;
            if self.episode_ms.is_nan() {
                self.episode_ms = sig.now_ms;
            }
            self.above += 1;
            if self.above >= self.need {
                // the tick counter resets (rate limit between fires) but
                // the episode anchor survives: if the event loop drops
                // this decision at the max-active bound, the eventual
                // wake still reports the full reaction time since
                // pressure began
                self.above = 0;
                return ScaleDecision::Up { since_ms: self.episode_ms };
            }
        } else if sig.queue_ewma < self.low {
            self.above = 0;
            self.episode_ms = f64::NAN;
            self.below += 1;
            if self.below >= self.need {
                self.below = 0;
                return ScaleDecision::Down;
            }
        } else {
            // inside the dead band: hold, and forget partial episodes
            self.above = 0;
            self.below = 0;
            self.episode_ms = f64::NAN;
        }
        ScaleDecision::Hold
    }
}

/// Attainment controller: hold EWMA SLO attainment inside the
/// `[ATTAIN_LOW, ATTAIN_HIGH]` band. Below the band for
/// [`ATTAIN_UP_TICKS`] → wake a server; above it for the (deliberately
/// longer) [`ATTAIN_DOWN_TICKS`] → drain one. The asymmetric tick counts
/// are the hysteresis: capacity is added eagerly and released lazily.
pub struct AttainmentPolicy {
    low: f64,
    high: f64,
    up_need: u32,
    down_need: u32,
    below: u32,
    above: u32,
    episode_ms: f64,
}

impl AttainmentPolicy {
    /// Controller with an explicit attainment band (`0 <= low < high <= 1`)
    /// and per-direction consecutive-tick requirements.
    pub fn new(low: f64, high: f64, up_need: u32, down_need: u32) -> AttainmentPolicy {
        AttainmentPolicy {
            low,
            high,
            up_need: up_need.max(1),
            down_need: down_need.max(1),
            below: 0,
            above: 0,
            episode_ms: f64::NAN,
        }
    }
}

impl AutoscalePolicy for AttainmentPolicy {
    fn name(&self) -> &'static str {
        ScalePolicy::NAMES[2]
    }

    fn decide(&mut self, _view: &FleetView, sig: &ScaleSignals) -> ScaleDecision {
        if sig.attainment_ewma < self.low {
            self.above = 0;
            if self.episode_ms.is_nan() {
                self.episode_ms = sig.now_ms;
            }
            self.below += 1;
            if self.below >= self.up_need {
                // as in [`QueueDepthPolicy`]: the counter resets, the
                // episode anchor persists until the signal recovers
                self.below = 0;
                return ScaleDecision::Up { since_ms: self.episode_ms };
            }
        } else if sig.attainment_ewma > self.high {
            self.below = 0;
            self.episode_ms = f64::NAN;
            self.above += 1;
            if self.above >= self.down_need {
                self.above = 0;
                return ScaleDecision::Down;
            }
        } else {
            self.below = 0;
            self.above = 0;
            self.episode_ms = f64::NAN;
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A trivial all-active FleetView over `n` servers (the policies under
    /// test decide from the EWMA signals; the view is along for the ride).
    struct ViewState {
        backlog: Vec<f64>,
        queued: Vec<usize>,
        resident: Vec<Vec<bool>>,
        unavail: Vec<bool>,
    }

    impl ViewState {
        fn new(n: usize) -> ViewState {
            ViewState {
                backlog: vec![0.0; n],
                queued: vec![0; n],
                resident: vec![vec![true]; n],
                unavail: vec![false; n],
            }
        }

        fn view(&self, now: f64) -> FleetView<'_> {
            FleetView {
                now_ms: now,
                backlog_ms: &self.backlog,
                queued: &self.queued,
                resident: &self.resident,
                unavailable: &self.unavail,
            }
        }
    }

    /// Hand-built signal for a tick: only the fields a policy reads vary.
    fn sig(now: f64, queue_ewma: f64, attain_ewma: f64) -> ScaleSignals {
        ScaleSignals {
            now_ms: now,
            active: 2,
            waking: 0,
            draining: 0,
            asleep: 2,
            queue_per_active: queue_ewma,
            queue_ewma,
            window_attainment: attain_ewma,
            attainment_ewma: attain_ewma,
        }
    }

    #[test]
    fn queue_depth_scale_up_needs_consecutive_pressure() {
        let st = ViewState::new(4);
        let mut p = QueueDepthPolicy::new(8.0, 1.0, 2);
        // tick 1 above the mark: episode starts, no decision yet
        assert_eq!(p.decide(&st.view(100.0), &sig(100.0, 12.0, 0.5)), ScaleDecision::Hold);
        // tick 2 still above: fire, reaction clock anchored at tick 1
        assert_eq!(
            p.decide(&st.view(150.0), &sig(150.0, 14.0, 0.5)),
            ScaleDecision::Up { since_ms: 100.0 }
        );
        // the tick counter resets (a rate limit between fires) but the
        // episode anchor persists while pressure holds: a re-fire — e.g.
        // after the event loop dropped the first decision at the
        // max-active bound — still reports the original episode start
        assert_eq!(p.decide(&st.view(200.0), &sig(200.0, 14.0, 0.5)), ScaleDecision::Hold);
        assert_eq!(
            p.decide(&st.view(250.0), &sig(250.0, 14.0, 0.5)),
            ScaleDecision::Up { since_ms: 100.0 }
        );
    }

    #[test]
    fn queue_depth_dead_band_holds_and_resets_episodes() {
        let st = ViewState::new(4);
        let mut p = QueueDepthPolicy::new(8.0, 1.0, 2);
        assert_eq!(p.decide(&st.view(0.0), &sig(0.0, 12.0, 1.0)), ScaleDecision::Hold);
        // dip into the dead band: the half-built episode is forgotten
        assert_eq!(p.decide(&st.view(50.0), &sig(50.0, 4.0, 1.0)), ScaleDecision::Hold);
        assert_eq!(p.decide(&st.view(100.0), &sig(100.0, 12.0, 1.0)), ScaleDecision::Hold);
        assert_eq!(
            p.decide(&st.view(150.0), &sig(150.0, 12.0, 1.0)),
            ScaleDecision::Up { since_ms: 100.0 },
            "episode must restart after the dead-band reset"
        );
    }

    #[test]
    fn queue_depth_drains_after_sustained_idleness() {
        let st = ViewState::new(4);
        let mut p = QueueDepthPolicy::new(8.0, 1.0, 2);
        assert_eq!(p.decide(&st.view(0.0), &sig(0.0, 0.2, 1.0)), ScaleDecision::Hold);
        assert_eq!(p.decide(&st.view(50.0), &sig(50.0, 0.1, 1.0)), ScaleDecision::Down);
        // and again, independently
        assert_eq!(p.decide(&st.view(100.0), &sig(100.0, 0.0, 1.0)), ScaleDecision::Hold);
        assert_eq!(p.decide(&st.view(150.0), &sig(150.0, 0.0, 1.0)), ScaleDecision::Down);
    }

    #[test]
    fn attainment_band_has_asymmetric_hysteresis() {
        let st = ViewState::new(4);
        let mut p = AttainmentPolicy::new(0.92, 0.99, 2, 3);
        // below the band: up after 2 ticks, anchored at the first
        assert_eq!(p.decide(&st.view(0.0), &sig(0.0, 0.0, 0.80)), ScaleDecision::Hold);
        assert_eq!(
            p.decide(&st.view(50.0), &sig(50.0, 0.0, 0.85)),
            ScaleDecision::Up { since_ms: 0.0 }
        );
        // inside the band: hold forever
        for t in 0..5 {
            assert_eq!(
                p.decide(&st.view(100.0 + t as f64), &sig(100.0 + t as f64, 0.0, 0.95)),
                ScaleDecision::Hold
            );
        }
        // above the band: down only after the longer 3-tick run
        assert_eq!(p.decide(&st.view(200.0), &sig(200.0, 0.0, 0.995)), ScaleDecision::Hold);
        assert_eq!(p.decide(&st.view(250.0), &sig(250.0, 0.0, 0.995)), ScaleDecision::Hold);
        assert_eq!(p.decide(&st.view(300.0), &sig(300.0, 0.0, 0.995)), ScaleDecision::Down);
    }

    #[test]
    fn attainment_up_run_is_broken_by_recovery() {
        let st = ViewState::new(2);
        let mut p = AttainmentPolicy::new(0.92, 0.99, 2, 3);
        assert_eq!(p.decide(&st.view(0.0), &sig(0.0, 0.0, 0.80)), ScaleDecision::Hold);
        // recovery into the band resets the below-run
        assert_eq!(p.decide(&st.view(50.0), &sig(50.0, 0.0, 0.95)), ScaleDecision::Hold);
        assert_eq!(p.decide(&st.view(100.0), &sig(100.0, 0.0, 0.80)), ScaleDecision::Hold);
        assert_eq!(
            p.decide(&st.view(150.0), &sig(150.0, 0.0, 0.80)),
            ScaleDecision::Up { since_ms: 100.0 }
        );
    }

    #[test]
    fn signal_tracker_differences_cumulative_counters() {
        let mut t = SignalTracker::new();
        // idle first window: attainment neutral at 1.0, queues empty
        let s = t.tick(100.0, 0, 0, 0, 2, 0, 0, 0);
        assert_eq!(s.window_attainment, 1.0);
        assert_eq!(s.attainment_ewma, 1.0);
        assert_eq!(s.queue_ewma, 0.0);
        // window with 10 outcomes, 5 attained: window attainment 0.5,
        // EWMA halfway between 1.0 and 0.5
        let s = t.tick(200.0, 10, 5, 8, 2, 0, 0, 0);
        assert_eq!(s.window_attainment, 0.5);
        assert!((s.attainment_ewma - 0.75).abs() < 1e-12);
        assert_eq!(s.queue_per_active, 4.0);
        assert!((s.queue_ewma - 2.0).abs() < 1e-12);
        // next window only sees the *delta*: 10 more outcomes, all attained
        let s = t.tick(300.0, 20, 15, 0, 2, 0, 0, 0);
        assert_eq!(s.window_attainment, 1.0);
        assert!((s.attainment_ewma - 0.875).abs() < 1e-12);
    }

    #[test]
    fn parse_scale_policy_names() {
        assert_eq!(ScalePolicy::parse("off"), Some(ScalePolicy::Off));
        assert_eq!(ScalePolicy::parse("queue-depth"), Some(ScalePolicy::QueueDepth));
        assert_eq!(ScalePolicy::parse("qd"), Some(ScalePolicy::QueueDepth));
        assert_eq!(ScalePolicy::parse("attainment"), Some(ScalePolicy::Attainment));
        assert_eq!(ScalePolicy::parse("at"), Some(ScalePolicy::Attainment));
        assert_eq!(ScalePolicy::parse("predictive"), Some(ScalePolicy::Predictive));
        assert_eq!(ScalePolicy::parse("pred"), Some(ScalePolicy::Predictive));
        assert!(ScalePolicy::parse("elastic").is_none());
        // NAMES is the single source of truth: round-trips, and build()
        // yields a controller for everything but Off
        let cfg = AutoscaleConfig::off();
        for (i, name) in ScalePolicy::NAMES.iter().enumerate() {
            let p = ScalePolicy::parse(name).expect("every listed name must parse");
            assert_eq!(p, ScalePolicy::ALL[i]);
            assert_eq!(p.name(), *name);
            assert_eq!(p.build(&cfg).is_some(), p != ScalePolicy::Off);
        }
    }

    #[test]
    fn off_config_is_inert() {
        let cfg = AutoscaleConfig::off();
        assert!(!cfg.enabled());
        assert!(cfg.policy.build(&cfg).is_none());
        let on = AutoscaleConfig { policy: ScalePolicy::QueueDepth, ..AutoscaleConfig::off() };
        assert!(on.enabled());
        assert_eq!(on.policy.build(&on).unwrap().name(), "queue-depth");
    }
}
