//! `cargo bench --bench bench_micro` — L3 hot-path micro benchmarks
//! (the §Perf profiling substrate; before/after numbers recorded in
//! EXPERIMENTS.md §Perf).
//!
//! Covers every stage the coordinator touches per Algorithm-1 iteration:
//! parameter cloning + masking, parameter upload, PJRT execution of each
//! artifact, the accuracy reduction, KL calibration, weight quantization,
//! liveness + graph optimization + roofline pricing, and the serialization
//! substrates (npy/JSON).

use hqp::benchkit::{bench, section};
use hqp::gopt::{optimize, OptimizeOptions};
use hqp::graph::{full_masks, Graph, Liveness};
use hqp::hwsim::{simulate, Device};
use hqp::quant::{quantize_per_channel, Calibrator, CalibMethod};
use hqp::runtime::{Session, Workspace};
use hqp::tensor::{argmax_rows, Tensor};
use hqp::testkit::prng::Prng;

fn main() {
    let ws = Workspace::open("artifacts").expect("run `make artifacts` first");
    let model = "resnet18";
    let mut sess = Session::new(&ws, model).expect("session");
    let params = sess.baseline.clone();
    let mm = sess.mm.clone();

    // ---------------- runtime layer ----------------------------------------
    section("runtime (PJRT) — per-call costs");
    println!(
        "{}",
        bench("params.clone (177k f32)", 3, 50, || params.clone()).line()
    );
    let g0 = mm.groups[2].clone();
    println!(
        "{}",
        bench("mask_filter (1 filter, all members)", 3, 200, || {
            let mut p = params.clone();
            p.mask_filter(&g0, 0).unwrap()
        })
        .line()
    );
    println!(
        "{}",
        bench("accuracy val-sweep (4x b256 exec)", 1, 5, || {
            sess.accuracy(&params, "val").unwrap()
        })
        .line()
    );
    println!(
        "{}",
        bench("quant_accuracy val-sweep", 1, 3, || {
            let scales = vec![0.05f32; mm.taps.len()];
            sess.quant_accuracy(&params, &scales, "val").unwrap()
        })
        .line()
    );
    println!(
        "{}",
        bench("act_absmax calib-sweep", 1, 3, || sess.act_absmax(&params).unwrap()).line()
    );
    let ranges = sess.act_absmax(&params).unwrap();
    println!(
        "{}",
        bench("act_hist calib-sweep", 1, 3, || sess.act_hist(&params, &ranges).unwrap()).line()
    );
    println!(
        "{}",
        bench("fisher 128-sample pass", 1, 3, || {
            sess.fisher_scores(&params, 128).unwrap()
        })
        .line()
    );

    // ---------------- quant layer -------------------------------------------
    section("quant — calibration & projection");
    let hist = sess.act_hist(&params, &ranges).unwrap();
    let bins = hist.shape()[1];
    let kl = Calibrator::new(CalibMethod::Kl);
    println!(
        "{}",
        bench("KL sweep (1 tap, 2048 bins)", 3, 100, || {
            kl.threshold(&hist.data()[..bins], ranges[0])
        })
        .line()
    );
    println!(
        "{}",
        bench("KL calibration (all taps)", 2, 20, || {
            (0..mm.taps.len())
                .map(|i| kl.threshold(&hist.data()[i * bins..(i + 1) * bins], ranges[i]))
                .collect::<Vec<_>>()
        })
        .line()
    );
    let big_w = params.get("stage3.block0.conv1.w").unwrap().clone();
    println!(
        "{}",
        bench("per-channel int8 projection (36k w)", 3, 100, || {
            quantize_per_channel(&big_w, 3, 8).unwrap()
        })
        .line()
    );

    // ---------------- graph/deploy layer ------------------------------------
    section("gopt + hwsim — deployment pipeline");
    let graph = Graph::from_manifest(&mm).unwrap();
    let masks = full_masks(&graph);
    println!(
        "{}",
        bench("liveness analysis", 3, 500, || {
            Liveness::analyze(&graph, &masks).unwrap()
        })
        .line()
    );
    println!(
        "{}",
        bench("optimize (fuse+dce+autotune)", 3, 200, || {
            optimize(&graph, &masks, &OptimizeOptions::int8()).unwrap()
        })
        .line()
    );
    let eng = optimize(&graph, &masks, &OptimizeOptions::int8()).unwrap();
    let dev = Device::xavier_nx();
    println!(
        "{}",
        bench("roofline simulate", 3, 2000, || simulate(&eng, &dev)).line()
    );

    // ---------------- substrates --------------------------------------------
    section("substrates — reductions & serialization");
    let mut rng = Prng::new(1);
    let logits = Tensor::new(
        vec![256, 10],
        (0..2560).map(|_| rng.next_f32()).collect(),
    )
    .unwrap();
    println!(
        "{}",
        bench("argmax_rows (256x10)", 3, 2000, || argmax_rows(&logits)).line()
    );
    let t = Tensor::new(vec![64, 64], (0..4096).map(|i| i as f32).collect()).unwrap();
    let dir = std::env::temp_dir().join("hqp_bench_npy");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("b.npy");
    println!(
        "{}",
        bench("npy write+read (16 KB)", 3, 200, || {
            hqp::formats::npy::write_npy_f32(&p, &t).unwrap();
            hqp::formats::npy::read_npy_f32(&p).unwrap()
        })
        .line()
    );
    let manifest_text =
        std::fs::read_to_string(ws.root.join("manifest.json")).unwrap();
    println!(
        "{}",
        bench(
            &format!("json parse manifest ({} KB)", manifest_text.len() / 1024),
            2,
            20,
            || hqp::formats::json::Json::parse(&manifest_text).unwrap()
        )
        .line()
    );
}
