//! `cargo bench --bench bench_session [-- --smoke]` — measurement-loop
//! perf: the incremental device-buffer cache, the copy-on-write
//! [`ParamStore`] clone, and early-exit bounded validation.
//!
//! Emits `BENCH_session.json` (benchkit [`Report`]) with both timing stats
//! and the counter-derived effectiveness metrics, so the perf trajectory of
//! the HQP measurement hot path is tracked from this PR onward:
//!
//! * `upload_bytes_cold`      — parameter bytes a cold call moves
//! * `upload_bytes_step`      — bytes one accepted δ-step re-uploads
//! * `upload_ratio`           — cold / step (acceptance floor: ≥ 5×)
//! * `bounded_batches_saved`  — validation batches early exit avoided on a
//!                              collapsed candidate
//! * `e2e_batches_skipped`    — batches the full HQP pipeline skipped
//!
//! `--smoke` shrinks iteration counts (CI) and skips the e2e pipeline; the
//! host-side section runs even without artifacts so the bench always
//! produces a report.

use hqp::benchkit::{bench, section, Report};
use hqp::hqp::{pipeline, HqpConfig};
use hqp::runtime::{ParamStore, Session, Workspace};
use hqp::tensor::Tensor;

fn host_side(report: &mut Report, iters: usize) {
    section("host side — copy-on-write ParamStore");
    // a model-shaped store: a few conv-like tensors + BN vectors
    let named: Vec<(String, Tensor)> = (0..16)
        .flat_map(|i| {
            vec![
                (format!("b{i}.w"), Tensor::full(vec![3, 3, 16, 32], 0.5)),
                (format!("b{i}.gamma"), Tensor::full(vec![32], 1.0)),
                (format!("b{i}.beta"), Tensor::full(vec![32], 0.0)),
            ]
        })
        .collect();
    let store = ParamStore::from_tensors(named);
    report.push(bench("paramstore.clone (cow, 48 slots)", 3, iters, || {
        store.clone()
    }));
    report.push(bench("clone + mask 1 filter (cow write)", 3, iters, || {
        let mut c = store.clone();
        c.get_mut("b0.gamma").unwrap().data_mut()[0] = 0.0;
        c
    }));
    report.metric("paramstore_bytes", store.num_bytes() as f64);
}

fn device_side(report: &mut Report, smoke: bool) {
    let root = std::env::var("HQP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&root).join("manifest.json").exists() {
        println!("\n(no artifacts under {root} — skipping PJRT sections; run `make artifacts`)");
        return;
    }
    let ws = Workspace::open(&root).expect("workspace");
    let model = "resnet18";
    let mut sess = Session::new(&ws, model).expect("session");
    let params = sess.baseline.clone();
    let mm = sess.mm.clone();
    let total = mm.total_filters();
    let step = ((total as f64 * 0.01).round() as usize).max(1); // paper δ = 1 %
    let (warm_iters, eval_iters) = if smoke { (5, 1) } else { (30, 5) };

    // ---- upload: cold vs dirty-only ---------------------------------------
    section("device side — parameter upload (cold vs dirty-only)");
    report.push(bench("upload cold (full model)", 1, warm_iters, || {
        sess.reset_param_cache();
        sess.warm_params(&params).unwrap()
    }));
    sess.warm_params(&params).unwrap();
    // evolve ONE store like the real accept loop does (masks accumulate):
    // a fresh clone of pristine params each iteration would also revert the
    // previous window's slots and double the measured upload set
    let mut cand = params.clone();
    let mut j = 0usize;
    report.push(bench("upload dirty-only (1 δ-step of filters)", 1, warm_iters, || {
        for f in 0..step {
            let (g, k) = mm.locate_filter((j + f) % total).unwrap();
            cand.mask_filter(g, k).unwrap();
        }
        j = (j + step) % total;
        sess.warm_params(&cand).unwrap()
    }));

    // counter-derived byte accounting for one accepted prune step
    sess.reset_param_cache();
    let before = sess.counters;
    sess.warm_params(&params).unwrap();
    let cold_bytes = sess.counters.upload_bytes - before.upload_bytes;
    let mut accepted = params.clone();
    for f in 0..step {
        let (g, k) = mm.locate_filter(f).unwrap();
        accepted.mask_filter(g, k).unwrap();
    }
    let before = sess.counters;
    sess.warm_params(&accepted).unwrap();
    let step_bytes = sess.counters.upload_bytes - before.upload_bytes;
    report.metric("upload_bytes_cold", cold_bytes as f64);
    report.metric("upload_bytes_step", step_bytes as f64);
    let ratio = cold_bytes as f64 / (step_bytes as f64).max(1.0);
    report.metric("upload_ratio", ratio);
    assert!(
        ratio >= 5.0,
        "acceptance floor: dirty-only upload must move ≥5x fewer bytes \
         (cold {cold_bytes} vs step {step_bytes})"
    );

    // ---- validation: full sweep vs bounded early exit ---------------------
    section("device side — full vs early-exit validation");
    let base_acc = sess.accuracy(&params, "val").unwrap();
    // a collapsed candidate: masking the most filters the manifest allows
    // makes the reject decision fall out of the first batch or two
    let mut collapsed = params.clone();
    for f in 0..total / 2 {
        let (g, k) = mm.locate_filter(f).unwrap();
        collapsed.mask_filter(g, k).unwrap();
    }
    report.push(bench("accuracy full sweep (candidate)", 1, eval_iters, || {
        sess.accuracy(&collapsed, "val").unwrap()
    }));
    report.push(bench("accuracy_bounded (same candidate)", 1, eval_iters, || {
        sess.accuracy_bounded(&collapsed, "val", base_acc, 0.015).unwrap()
    }));
    let full = sess.accuracy(&collapsed, "val").unwrap();
    let bounded = sess
        .accuracy_bounded(&collapsed, "val", base_acc, 0.015)
        .unwrap();
    assert_eq!(
        bounded.accepted,
        base_acc - full <= 0.015,
        "bounded decision must equal the full-sweep decision"
    );
    report.metric("bounded_batches_run", bounded.batches_run as f64);
    report.metric("bounded_batches_saved", bounded.batches_skipped as f64);

    // ---- e2e: the conditional loop with caching + early exit --------------
    if !smoke {
        section("e2e — HQP pipeline counters");
        let mut e2e = Session::new(&ws, model).expect("session");
        let cfg = HqpConfig {
            delta_step_frac: 0.02,
            calib_samples: 128,
            ..Default::default()
        };
        pipeline::run_hqp(&mut e2e, &cfg).expect("hqp");
        let c = e2e.counters;
        report.metric("e2e_executions", c.executions as f64);
        report.metric("e2e_upload_tensors", c.upload_tensors as f64);
        report.metric("e2e_upload_bytes", c.upload_bytes as f64);
        report.metric("e2e_batches_skipped", c.batches_skipped as f64);
        let cold = params.num_bytes() as f64;
        let steps = (c.executions as f64 / 4.0).max(1.0); // ~4 val batches/sweep
        println!(
            "  (cold model = {cold:.0} B; uploaded {:.0} B over ~{steps:.0} sweeps)",
            c.upload_bytes as f64
        );
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let iters = if smoke { 20 } else { 200 };
    let mut report = Report::new();
    host_side(&mut report, iters);
    device_side(&mut report, smoke);
    report.write_json("BENCH_session.json").expect("write BENCH_session.json");
    println!("\nwrote BENCH_session.json");
}
