//! `cargo bench --bench bench_search [-- --smoke]` — the budgeted
//! schedule search on the §V-B axis, plus its determinism contract.
//!
//! Runs without artifacts against the reference surrogate backend.
//! Emits `BENCH_search.json` (benchkit [`Report`]):
//!
//! * `search_budget` / `search_evals` — configured cap vs evaluations
//!                                      actually spent (acceptance:
//!                                      evals ≤ budget)
//! * `search_front_size`              — points on the ranked Pareto front
//! * `search_wall_ms_jobs1` / `search_wall_ms_jobsN` / `search_speedup`
//!                                    — full-rung pool wall-clock,
//!                                      sequential vs parallel
//! * `prune_first_acc_drop` / `quantize_first_acc_drop`
//!                                    — the §V-B ordering ablation as the
//!                                      search rediscovered it
//! * `prune_first_speedup` / `prune_first_compliant` /
//!   `quantize_first_compliant`      — acceptance: at equal Δ_max,
//!                                      `prune >> ptq` is on the front and
//!                                      `ptq >> prune` is hard-excluded
//!
//! The jobs=N run's rendered front is asserted byte-identical to the
//! jobs=1 run's — parallel search may never cost determinism.

use hqp::benchkit::{section, Report};
use hqp::exec::Jobs;
use hqp::hqp::HqpConfig;
use hqp::hwsim::Device;
use hqp::search::{outcome_json, render, run_search, Backend, SearchConfig, SearchSpace};

fn config(budget: usize, jobs: Jobs) -> SearchConfig {
    SearchConfig {
        model: "resnet18".into(),
        device: Device::xavier_nx(),
        hqp: HqpConfig::default(),
        budget,
        seed: 42,
        space: SearchSpace::all(),
        jobs,
        backend: Backend::Reference,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = Report::new();

    section("search — budgeted schedule search over the grammar");
    let budget = if smoke { 8 } else { 64 };

    let sc1 = config(budget, Jobs::one());
    let out1 = run_search(&sc1).expect("search (jobs=1)");
    let jobs = Jobs::available();
    let scn = config(budget, jobs);
    let outn = run_search(&scn).expect("search (jobs=N)");

    // determinism contract: byte-identical front and JSON at any --jobs
    assert_eq!(
        render(&sc1, &out1),
        render(&scn, &outn),
        "rendered front diverged between jobs=1 and jobs={}",
        jobs.get()
    );
    assert_eq!(
        outcome_json(&sc1, &out1).to_string_pretty(),
        outcome_json(&scn, &outn).to_string_pretty(),
        "outcome JSON diverged between jobs=1 and jobs={}",
        jobs.get()
    );

    // budget contract
    assert!(
        out1.evals() <= budget,
        "spent {} evaluations against --budget {budget}",
        out1.evals()
    );

    // §V-B acceptance: the front rediscovers that prune-then-quantize
    // dominates quantize-then-prune at equal Δ_max
    let full_of = |s: &str| out1.full.iter().find(|e| e.schedule == s);
    let pf = full_of("prune >> ptq").expect("prune-first must be promoted to full fidelity");
    let qf = full_of("ptq >> prune").expect("quantize-first must be promoted to full fidelity");
    assert!(pf.compliant, "prune-first must meet Δ_max");
    assert!(!qf.compliant, "quantize-first must violate Δ_max (stale scales)");
    assert!(pf.acc_drop < qf.acc_drop);
    assert!(
        out1.front.iter().any(|e| e.schedule == "prune >> ptq"),
        "prune-first missing from the front"
    );
    assert!(
        !out1.front.iter().any(|e| e.schedule == "ptq >> prune"),
        "Δ_max violator on the front"
    );

    print!("{}", render(&sc1, &out1));
    for pool in &outn.pools {
        print!("{}", pool.render());
    }

    report.metric("search_budget", budget as f64);
    report.metric("search_evals", out1.evals() as f64);
    report.metric("search_cheap_evals", out1.cheap_evals as f64);
    report.metric("search_full_evals", out1.full_evals as f64);
    report.metric("search_front_size", out1.front.len() as f64);
    report.metric("search_jobs", jobs.get() as f64);
    let wall1: f64 = out1.pools.iter().map(|p| p.wall_ms).sum();
    let walln: f64 = outn.pools.iter().map(|p| p.wall_ms).sum();
    report.metric("search_wall_ms_jobs1", wall1);
    report.metric("search_wall_ms_jobsN", walln);
    report.metric("search_speedup", wall1 / walln.max(1e-9));
    report.metric("prune_first_acc_drop", pf.acc_drop);
    report.metric("quantize_first_acc_drop", qf.acc_drop);
    report.metric("prune_first_speedup", pf.speedup);
    report.metric("prune_first_compliant", if pf.compliant { 1.0 } else { 0.0 });
    report.metric("quantize_first_compliant", if qf.compliant { 1.0 } else { 0.0 });

    report.write_json("BENCH_search.json").expect("write BENCH_search.json");
    println!("\nwrote BENCH_search.json");
}
