//! `cargo bench --bench bench_figures` — regenerates the paper's FIGURES
//! and section analyses:
//!
//!   * Fig. 2 — latency + accuracy bars (MobileNetV3, Xavier NX)
//!   * Fig. 3 — size reduction vs accuracy drop scatter (all methods)
//!   * §V-C  — layer-wise sparsity profile (non-uniform sparsity claim)
//!   * §V-E  — energy analysis (E = P·L identity, both devices)
//!   * §III-C/§V-F — C_HQP vs C_QAT overhead
//!   * sparsity–accuracy trajectory of Algorithm 1 (the Pareto story)
//!
//! Reads the cached method results (bench_tables populates them; anything
//! missing is computed here at paper parameters).

use hqp::benchkit::section;
use hqp::coordinator::{experiments, run_method, MethodSpec, ResultRow};
use hqp::hqp::{cost, pipeline, HqpConfig};
use hqp::hwsim::Device;
use hqp::report::{bar_chart, scatter, BarRow};
use hqp::runtime::{Session, Workspace};

fn suite(ws: &Workspace, model: &str, cfg: &HqpConfig) -> Vec<ResultRow> {
    let devices = Device::all();
    let force = std::env::var("HQP_FORCE").is_ok();
    let mut rows = Vec::new();
    for spec in [
        MethodSpec::Baseline,
        MethodSpec::Q8Only,
        MethodSpec::PruneOnly(50),
        MethodSpec::Hqp,
    ] {
        rows.extend(run_method(ws, model, spec, cfg, &devices, force).expect("method"));
    }
    rows
}

fn main() {
    let ws = Workspace::open("artifacts").expect("run `make artifacts` first");
    let cfg = HqpConfig::default();

    // ---------------- Fig. 2 ------------------------------------------------
    section("Fig. 2 — MobileNetV3 on Xavier NX");
    let rows = suite(&ws, "mobilenetv3", &cfg);
    let nx = experiments::reports_for_device(&rows, "xavier-nx");
    let lat: Vec<BarRow> = nx
        .iter()
        .map(|r| {
            BarRow::new(
                r.method.clone(),
                r.latency_ms,
                format!("{:.3} ms ({:.2}x)", r.latency_ms, r.speedup),
            )
        })
        .collect();
    println!("{}", bar_chart("Fig. 2a — Latency by method", &lat, 48));
    let acc: Vec<BarRow> = nx
        .iter()
        .map(|r| {
            BarRow::new(
                r.method.clone(),
                (r.acc_drop * 100.0).max(0.0),
                format!(
                    "{:.2}% drop{}",
                    r.acc_drop * 100.0,
                    if r.compliant { "" } else { "   << VIOLATES Δmax=1.5%" }
                ),
            )
        })
        .collect();
    println!("{}", bar_chart("Fig. 2b — Accuracy drop by method", &acc, 48));

    // ---------------- Fig. 3 ------------------------------------------------
    section("Fig. 3 — size reduction vs accuracy drop");
    let mut pts = Vec::new();
    for model in ["mobilenetv3", "resnet18"] {
        let rows = suite(&ws, model, &cfg);
        for r in experiments::reports_for_device(&rows, "xavier-nx") {
            pts.push((
                r.size_reduction * 100.0,
                r.acc_drop * 100.0,
                format!("{model}/{}", r.method),
            ));
        }
    }
    println!(
        "{}",
        scatter(
            "Fig. 3 — Model size reduction vs accuracy drop (Xavier NX)",
            &pts,
            "size reduction %",
            "accuracy drop %",
            60,
            14
        )
    );

    // ---------------- §V-C layer-wise profile -------------------------------
    section("§V-C — layer-wise sparsity (MobileNetV3, HQP)");
    let rows = suite(&ws, "mobilenetv3", &cfg);
    let hqp_row = rows
        .iter()
        .find(|r| r.report.method == "hqp" && r.report.device == "xavier-nx")
        .expect("hqp row");
    let mm = ws.manifest.model("mobilenetv3").unwrap();
    let bars: Vec<BarRow> = mm
        .groups
        .iter()
        .zip(&hqp_row.group_sparsity)
        .map(|(g, &s)| {
            BarRow::new(
                g.name.clone(),
                s * 100.0,
                format!("θ={:>3.0}%  S̄={:.2e}", s * 100.0,
                        hqp_row.group_saliency.get(g.id).copied().unwrap_or(0.0)),
            )
        })
        .collect();
    println!("{}", bar_chart("per-group sparsity (paper: shallow/deep low, mid high)", &bars, 40));

    // ---------------- Algorithm 1 trajectory --------------------------------
    section("Algorithm 1 — sparsity-accuracy trajectory");
    for model in ["mobilenetv3", "resnet18"] {
        let rows = suite(&ws, model, &cfg);
        if let Some(r) = rows.iter().find(|r| r.report.method == "hqp" && !r.trace.is_empty()) {
            println!("{model}:");
            for (s, a, ok) in &r.trace {
                println!(
                    "  θ={:>5.1}%  acc {:.4}  {}",
                    s * 100.0,
                    a,
                    if *ok { "accept" } else { "REJECT -> terminate" }
                );
            }
        }
    }

    // ---------------- §V-E energy -------------------------------------------
    section("§V-E — energy per inference (E = P·L)");
    for model in ["mobilenetv3", "resnet18"] {
        let rows = suite(&ws, model, &cfg);
        for dev in [Device::jetson_nano(), Device::xavier_nx()] {
            println!("{model} on {}:", dev.name);
            for r in experiments::reports_for_device(&rows, &dev.name) {
                println!(
                    "  {:<10} {:>9.3} mJ   energy-ratio {:>5.2}x  == speedup {:>5.2}x : {}",
                    r.method,
                    r.energy_mj,
                    r.energy_ratio,
                    r.speedup,
                    (r.energy_ratio - r.speedup).abs() < 1e-9
                );
            }
        }
    }

    // ---------------- §III-C / §V-F overhead --------------------------------
    section("§III-C / §V-F — C_HQP vs C_QAT");
    for model in ["mobilenetv3", "resnet18"] {
        let mut sess = Session::new(&ws, model).expect("session");
        let (o, ms) = hqp::benchkit::time_once(|| pipeline::run_hqp(&mut sess, &cfg));
        o.expect("hqp");
        let h = cost::HqpCost::from_counters(&sess.counters);
        let qat = cost::QatCost::paper_default(8192);
        let qat_in = cost::QatCost::paper_default(1_281_167);
        println!(
            "{model}: C_HQP = {} grad + {} inf samples = {:.0} fwd-equiv ({:.1}s wall)",
            h.grad_samples,
            h.inference_samples,
            h.total_inf_equiv(),
            ms / 1e3
        );
        println!(
            "   C_QAT/C_HQP = {:.1}x (matched trainset)  |  {:.0}x (ImageNet-scale)",
            cost::overhead_ratio(&h, &qat),
            cost::overhead_ratio(&h, &qat_in)
        );
    }
}
