//! `cargo bench --bench bench_tables` — regenerates the paper's TABLES:
//!
//!   * Table I  — MobileNetV3 on Jetson Xavier NX (Baseline/Q8/P50/HQP)
//!   * Table II — ResNet-18  on Jetson Xavier NX (Baseline/Q8/HQP; P50
//!                included for completeness)
//!   * §IV-A heterogeneity — the same suites on Jetson Nano
//!
//! Methods run at paper parameters (Δ_max = 1.5 %, δ = 1 %, KL INT8) and
//! are cached under artifacts/results/ — use HQP_FORCE=1 to re-run the
//! pipelines instead of re-rendering. Timing of each pipeline stage is
//! printed alongside (this doubles as the coordinator-level macro bench).

use hqp::benchkit::{section, time_once};
use hqp::coordinator::{experiments, run_method, run_schedule, MethodSpec};
use hqp::hqp::{HqpConfig, Schedule};
use hqp::hwsim::Device;
use hqp::report;
use hqp::runtime::Workspace;

/// Paper rows for the side-by-side (speedup, drop %, θ %).
const PAPER_T1: &[(&str, f64, f64, f64)] = &[
    ("baseline", 1.00, 0.0, 0.0),
    ("q8-only", 1.58, 1.2, 0.0),
    ("p50-only", 1.35, 1.8, 50.0),
    ("hqp", 3.12, 1.4, 45.0),
];
const PAPER_T2: &[(&str, f64, f64, f64)] = &[
    ("baseline", 1.00, 0.0, 0.0),
    ("q8-only", 1.55, 1.9, 0.0),
    ("hqp", 2.51, 1.3, 35.0),
];

fn main() {
    let ws = Workspace::open("artifacts").expect("run `make artifacts` first");
    let force = std::env::var("HQP_FORCE").is_ok();
    let cfg = HqpConfig::default(); // paper parameters
    let devices = Device::all();

    for (table, model, paper) in [
        ("Table I", "mobilenetv3", PAPER_T1),
        ("Table II", "resnet18", PAPER_T2),
    ] {
        section(&format!("{table} — {model}"));
        let mut rows = Vec::new();
        for spec in [
            MethodSpec::Baseline,
            MethodSpec::Q8Only,
            MethodSpec::PruneOnly(50),
            MethodSpec::Hqp,
        ] {
            let (r, ms) = time_once(|| run_method(&ws, model, spec, &cfg, &devices, force));
            let r = r.expect("method run");
            println!("[{:>9.1} ms] {:?}", ms, spec);
            rows.extend(r);
        }
        let nx = experiments::reports_for_device(&rows, "xavier-nx");
        println!(
            "\n{}",
            report::method_table(
                &format!("{table} — {model}, edge-side inference on Jetson Xavier NX"),
                &nx
            )
        );
        println!("paper-vs-measured (speedup | drop% | θ%):");
        for (name, ps, pd, pt) in paper {
            if let Some(r) = nx.iter().find(|r| &r.method == name) {
                println!(
                    "  {:<10} paper {:>5.2}x / {:>4.1}% / {:>3.0}%   ours {:>5.2}x / {:>5.2}% / {:>3.0}%",
                    name, ps, pd, pt,
                    r.speedup, r.acc_drop * 100.0, r.sparsity * 100.0
                );
            }
        }

        // §IV-A heterogeneity: same engines on the Nano.
        let nano = experiments::reports_for_device(&rows, "jetson-nano");
        println!(
            "\n{}",
            report::method_table(
                &format!("§IV-A — {model} on Jetson Nano (no INT8 tensor cores)"),
                &nano
            )
        );
    }

    // §V-B ordering ablation — the schedule API's payoff experiment:
    // quantize-first (inexpressible under the closed MethodSpec enum)
    // against the paper's prune-first, same config, same model.
    section("§V-B ordering ablation — resnet18, prune>>ptq vs ptq>>prune");
    for spec in ["prune >> ptq", "ptq >> prune"] {
        let sched = Schedule::parse(spec).expect("ablation schedule");
        let (r, ms) =
            time_once(|| run_schedule(&ws, "resnet18", &sched, &cfg, &devices, force));
        let rows = r.expect("schedule run");
        for rep in experiments::reports_for_device(&rows, "xavier-nx") {
            println!(
                "[{ms:>9.1} ms] {:<14} drop {:>5.2}%  θ {:>4.1}%  speedup {:>5.2}x  Δmax ok: {}",
                rep.method,
                rep.acc_drop * 100.0,
                rep.sparsity * 100.0,
                rep.speedup,
                rep.compliant
            );
        }
    }
}
