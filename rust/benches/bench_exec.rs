//! `cargo bench --bench bench_exec [-- --smoke]` — worker-pool speedup
//! on a multi-candidate evaluation suite, and the determinism contract
//! under load.
//!
//! Runs without artifacts: the candidates are full fleet simulations
//! (policy × seed grid over the paper-anchored reference profiles), each
//! a CPU-bound task of the same shape `hqp run --method suite` fans out.
//! Emits `BENCH_exec.json` (benchkit [`Report`]):
//!
//! * `exec_tasks` / `exec_jobs`     — suite size and worker count used
//! * `wall_ms_jobs1` / `wall_ms_jobsN` — pool wall-clock, sequential vs
//!                                    parallel, from the pool's own
//!                                    counters ([`PoolReport`])
//! * `exec_speedup`                 — jobs1 / jobsN wall-clock ratio
//!                                    (acceptance: > 1x whenever the host
//!                                    has more than one core)
//! * `exec_busy_over_wall`          — total busy time / wall time at
//!                                    jobs=N (how well workers overlap)
//!
//! The parallel run's results are asserted identical to the sequential
//! run's, candidate by candidate — the speedup may never cost
//! determinism.

use hqp::benchkit::{section, Report};
use hqp::exec::{parallel_map, Jobs};
use hqp::hwsim::Device;
use hqp::serve::{
    reference_fleet, simulate_fleet, trace, ArrivalProcess, Policy, ServeConfig,
};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = Report::new();

    section("exec — worker pool on a multi-candidate serve suite");
    let dev = Device::xavier_nx();
    let fleet = reference_fleet(
        "resnet18",
        &[dev.clone()],
        &["baseline", "q8", "p50", "hqp", "mixed"],
        8,
    )
    .expect("fleet");
    let slo_ms = fleet.servers[0].variants[0].batch1_ms() * 4.0;
    let duration_ms = if smoke { 1_500.0 } else { 4_000.0 };

    // the candidate grid: every routing policy under several independent
    // traces — 12 CPU-bound tasks, no shared state between them
    let policies = [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest];
    let seeds: &[u64] = &[3, 7, 11, 19];
    let candidates: Vec<(Policy, u64)> = policies
        .iter()
        .flat_map(|p| seeds.iter().map(move |s| (*p, *s)))
        .collect();
    let run_candidate = |(policy, seed): (Policy, u64), _i: usize| {
        let arrivals =
            trace::generate(&ArrivalProcess::Poisson { rps: 400.0 }, duration_ms, seed);
        let cfg = ServeConfig { slo_ms, policy, ..Default::default() };
        simulate_fleet(&fleet, &arrivals, &cfg)
    };

    let (seq, seq_pool) =
        parallel_map(Jobs::one(), candidates.clone(), run_candidate).expect("sequential pool");
    let jobs = Jobs::available();
    let (par, par_pool) = parallel_map(jobs, candidates, run_candidate).expect("parallel pool");

    // determinism contract: same candidates, same results, any worker count
    assert_eq!(seq.len(), par.len());
    for (i, (a, b)) in seq.iter().zip(par.iter()).enumerate() {
        assert_eq!(a, b, "candidate {i} diverged between jobs=1 and jobs={}", jobs.get());
    }

    print!("{}", par_pool.render());
    report.metric("exec_tasks", par_pool.tasks as f64);
    report.metric("exec_jobs", jobs.get() as f64);
    report.metric("wall_ms_jobs1", seq_pool.wall_ms);
    report.metric("wall_ms_jobsN", par_pool.wall_ms);
    let speedup = seq_pool.wall_ms / par_pool.wall_ms.max(1e-9);
    report.metric("exec_speedup", speedup);
    report.metric("exec_busy_over_wall", par_pool.busy_ms_total() / par_pool.wall_ms.max(1e-9));
    if jobs.get() > 1 {
        assert!(
            speedup > 1.0,
            "acceptance: jobs={} must beat jobs=1 on {} candidates \
             ({:.1} ms vs {:.1} ms)",
            jobs.get(),
            par_pool.tasks,
            par_pool.wall_ms,
            seq_pool.wall_ms,
        );
    }

    report.write_json("BENCH_exec.json").expect("write BENCH_exec.json");
    println!("\nwrote BENCH_exec.json");
}
