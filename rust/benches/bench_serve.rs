//! `cargo bench --bench bench_serve [-- --smoke]` — serving-level
//! performance of the deployed HQP variants, and the simulator's own
//! event-loop throughput.
//!
//! Emits `BENCH_serve.json` (benchkit [`Report`]) so the serving
//! trajectory is tracked across PRs:
//!
//! * `offered_rps` / `slo_ms`        — the matched-load scenario
//! * `slo_attain_baseline|hqp`       — SLO attainment at the same offered
//!                                     load (acceptance: hqp strictly higher
//!                                     — the serving analogue of the paper's
//!                                     3.12x speedup)
//! * `p99_ms_baseline|hqp`           — tail latency under that load
//! * `throughput_rps_baseline|hqp`   — goodput under that load
//! * `capacity_rps_*`                — open-loop roofline capacities
//! * `slo_attain_static_best|swap_aware`, `swap_count`, `swap_ms`,
//!   `swap_energy_mj`,
//!   `swap_expired_mid`              — stateful residency: a 48 MB NX that
//!                                     can't hold baseline + hqp at once,
//!                                     under an MMPP burst (acceptance:
//!                                     swap-aware >= the best static policy,
//!                                     with at least one hot-swap charged)
//! * `slo_attain_fixed_mean|fixed_peak|autoscaled`, `scale_ups`,
//!   `scale_downs`, `wake_ms`, `wake_energy_mj`, `scale_reaction_ms`
//!                                   — elastic autoscaling: a 4-server hqp
//!                                     fleet under an MMPP burst, queue-depth
//!                                     controller (acceptance: autoscaled ≥
//!                                     the fixed fleet of equal *mean*
//!                                     capacity, with at least one scale-up
//!                                     and its wake cost + E = P·L charged)
//! * `wall_ms_*` / `events_per_sec_*`— per-scenario host cost: wall-clock
//!                                     and simulated events per wall-second
//!                                     ([`Summary::events`] counts arrivals,
//!                                     control ticks and every shard-local
//!                                     pop)
//! * `sim_events_per_sec`            — events/s the sharded virtual-time
//!                                     engine sustains (host-side, no
//!                                     artifacts; a hard floor is asserted)
//! * `stress_requests`, `stress_hist_bins`, `stress_peak_queue_depth`,
//!   `wall_ms_stress`, `events_per_sec_stress`
//!                                   — the streaming stress scenario: 10⁶
//!                                     requests (10⁴ with --smoke) through
//!                                     `simulate_fleet_stream` with the
//!                                     lazy trace generator (acceptance:
//!                                     the events/s floor holds AND the
//!                                     occupied-histogram-bin footprint is
//!                                     independent of the request count)
//!
//! Runs without artifacts: fleets come from the paper-anchored reference
//! profiles, so this bench (like `bench_session --smoke`) always produces
//! a report in CI.

use hqp::benchkit::{bench, section, time_once, Report};
use hqp::exec::Jobs;
use hqp::hwsim::Device;
use hqp::serve::{
    parse_tenants, reference_fleet, simulate_fleet, simulate_fleet_stream, trace,
    AdmitPolicy, ArrivalProcess, AutoscaleConfig, Policy, ScalePolicy, ServeConfig,
};

/// Every simulation must sustain at least this many simulated events per
/// wall-clock second — conservative enough for a loaded CI runner, loud
/// enough to catch an accidentally quadratic event loop.
const EVENTS_PER_SEC_FLOOR: f64 = 10_000.0;

/// Per-scenario host cost: wall-clock plus virtual-event throughput, with
/// the floor asserted at the point of measurement.
fn scenario_cost(report: &mut Report, name: &str, events: u64, wall_ms: f64) {
    let eps = events as f64 / (wall_ms / 1e3).max(1e-9);
    report.metric(&format!("wall_ms_{name}"), wall_ms);
    report.metric(&format!("events_per_sec_{name}"), eps);
    assert!(
        eps >= EVENTS_PER_SEC_FLOOR,
        "scenario {name}: {eps:.0} events/s is below the {EVENTS_PER_SEC_FLOOR:.0} floor"
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = Report::new();
    let dev = Device::xavier_nx();
    let duration_ms = if smoke { 1_000.0 } else { 4_000.0 };

    // ---- matched-load SLO comparison: baseline vs hqp ---------------------
    section("serve — SLO attainment at matched offered load (resnet18, xavier-nx)");
    let base_fleet = reference_fleet("resnet18", &[dev.clone()], &["baseline"], 8).expect("fleet");
    let hqp_fleet = reference_fleet("resnet18", &[dev.clone()], &["hqp"], 8).expect("fleet");
    let cap_base = base_fleet.servers[0].variants[0].capacity_rps();
    let cap_hqp = hqp_fleet.servers[0].variants[0].capacity_rps();
    // 2x the baseline's capacity: saturates fp32, well inside hqp's roof
    let offered = cap_base * 2.0;
    let slo_ms = base_fleet.servers[0].variants[0].batch1_ms() * 4.0;
    let cfg = ServeConfig { slo_ms, policy: Policy::AccFastest, ..Default::default() };
    let arrivals = trace::generate(&ArrivalProcess::Poisson { rps: offered }, duration_ms, 7);

    let (s_base, ms_base) = time_once(|| simulate_fleet(&base_fleet, &arrivals, &cfg));
    let s_base = s_base.expect("baseline sim");
    let (s_hqp, ms_hqp) = time_once(|| simulate_fleet(&hqp_fleet, &arrivals, &cfg));
    let s_hqp = s_hqp.expect("hqp sim");

    report.metric("offered_rps", offered);
    report.metric("slo_ms", slo_ms);
    report.metric("capacity_rps_baseline", cap_base);
    report.metric("capacity_rps_hqp", cap_hqp);
    report.metric("slo_attain_baseline", s_base.slo_attainment());
    report.metric("slo_attain_hqp", s_hqp.slo_attainment());
    report.metric("p99_ms_baseline", s_base.p99_ms);
    report.metric("p99_ms_hqp", s_hqp.p99_ms);
    report.metric("throughput_rps_baseline", s_base.throughput_rps);
    report.metric("throughput_rps_hqp", s_hqp.throughput_rps);
    assert!(
        s_hqp.slo_attainment() > s_base.slo_attainment(),
        "acceptance: hqp attainment {:.3} must strictly beat baseline {:.3} \
         at {offered:.0} rps",
        s_hqp.slo_attainment(),
        s_base.slo_attainment()
    );
    scenario_cost(&mut report, "matched_load", s_base.events + s_hqp.events, ms_base + ms_hqp);

    // ---- full fleet under the accuracy-constrained router -----------------
    section("serve — full variant fleet, acc-fastest router");
    let fleet = reference_fleet(
        "resnet18",
        &[dev.clone()],
        &["baseline", "q8", "p50", "hqp", "mixed"],
        8,
    )
    .expect("fleet");
    let (s_fleet, ms_fleet) = time_once(|| simulate_fleet(&fleet, &arrivals, &cfg));
    let s_fleet = s_fleet.expect("fleet sim");
    scenario_cost(&mut report, "full_fleet", s_fleet.events, ms_fleet);
    report.metric("fleet_slo_attain", s_fleet.slo_attainment());
    report.metric("fleet_acc_mix", s_fleet.acc_mix);
    report.metric("fleet_mean_batch", s_fleet.mean_batch);
    let p50_served = s_fleet
        .per_variant
        .iter()
        .find(|u| u.variant == "p50")
        .map(|u| u.completed)
        .unwrap_or(0);
    assert_eq!(p50_served, 0, "Δmax-violating p50 must never be scheduled");

    // ---- stateful residency: swap-aware vs static under capped memory -----
    section("serve — swap-aware hot-swap vs static policies (48 MB cap, mmpp burst)");
    let capped = reference_fleet("resnet18", &[dev.clone()], &["baseline", "hqp"], 8)
        .expect("fleet")
        .with_mem_cap_mb(48.0);
    assert_eq!(
        capped.servers[0].initial_residency(),
        vec![true, false],
        "48 MB holds baseline (~46.7 MB) but not baseline + hqp"
    );
    // fixed 4 s window even under --smoke: virtual time costs nothing, and
    // the asserted hot-swap needs the burst to actually arrive (the MMPP
    // starts in its low state)
    let burst =
        trace::generate(&ArrivalProcess::parse("mmpp", offered).unwrap(), 4_000.0, 13);
    let mut best_static = 0.0f64;
    let (mut swap_events, mut swap_wall_ms) = (0u64, 0.0f64);
    for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest] {
        let cfg = ServeConfig { slo_ms, policy, ..Default::default() };
        let (s, ms) = time_once(|| simulate_fleet(&capped, &burst, &cfg));
        let s = s.expect("static sim");
        assert_eq!(s.swaps, 0, "static policies never swap");
        best_static = best_static.max(s.slo_attainment());
        swap_events += s.events;
        swap_wall_ms += ms;
    }
    let swap_cfg = ServeConfig { slo_ms, policy: Policy::SwapAware, ..Default::default() };
    let (s_swap, ms_swap) = time_once(|| simulate_fleet(&capped, &burst, &swap_cfg));
    let s_swap = s_swap.expect("swap-aware sim");
    scenario_cost(&mut report, "swap_aware", swap_events + s_swap.events, swap_wall_ms + ms_swap);
    report.metric("slo_attain_static_best", best_static);
    report.metric("slo_attain_swap_aware", s_swap.slo_attainment());
    report.metric("swap_count", s_swap.swaps as f64);
    report.metric("swap_ms", s_swap.swap_ms);
    report.metric("swap_energy_mj", s_swap.swap_energy_mj);
    report.metric("swap_expired_mid", s_swap.expired_during_swap as f64);
    assert!(s_swap.swaps >= 1, "queue pressure through the burst must trigger a hot-swap");
    assert!(
        s_swap.swap_energy_mj > 0.0,
        "each hot-swap window must be charged E = P·L"
    );
    assert!(
        s_swap.slo_attainment() >= best_static,
        "acceptance: swap-aware {:.3} must reach at least the best static {:.3}",
        s_swap.slo_attainment(),
        best_static
    );

    // ---- elastic autoscaling: tracking an MMPP burst ----------------------
    section("serve — autoscaled vs fixed fleets under an mmpp burst (hqp on 4x nx)");
    // peak fleet: 4 hqp-only NX servers; mean offered load needs ~2.4 of
    // them, the burst's high state ~3.84 — so a fixed fleet at the *mean*
    // capacity (2 servers) sheds through every burst while the elastic
    // fleet (2..4 active, queue-depth controller) wakes capacity into it
    let one = reference_fleet("resnet18", &[dev.clone()], &["hqp"], 8).expect("fleet");
    let cap_one = one.servers[0].variants[0].capacity_rps();
    let slo_auto = one.servers[0].variants[0].batch1_ms() * 8.0;
    let peak_fleet = one.clone().replicate_to(4).expect("peak fleet");
    let mean_fleet = one.replicate_to(2).expect("mean fleet");
    // fixed 4 s window even under --smoke, same reasoning as the swap
    // scenario: the asserted scale-up needs the burst to actually arrive
    let auto_burst =
        trace::generate(&ArrivalProcess::parse("mmpp", cap_one * 2.4).unwrap(), 4_000.0, 17);
    let fixed_cfg = ServeConfig { slo_ms: slo_auto, ..Default::default() };
    let auto_cfg = ServeConfig {
        slo_ms: slo_auto,
        autoscale: AutoscaleConfig {
            policy: ScalePolicy::QueueDepth,
            interval_ms: 50.0,
            min_active: 2,
            max_active: 4,
            ..AutoscaleConfig::off()
        },
        ..Default::default()
    };
    let (s_mean, ms_mean) = time_once(|| simulate_fleet(&mean_fleet, &auto_burst, &fixed_cfg));
    let s_mean = s_mean.expect("fixed-mean sim");
    let (s_peak, ms_peak) = time_once(|| simulate_fleet(&peak_fleet, &auto_burst, &fixed_cfg));
    let s_peak = s_peak.expect("fixed-peak sim");
    let (s_auto, ms_auto) = time_once(|| simulate_fleet(&peak_fleet, &auto_burst, &auto_cfg));
    let s_auto = s_auto.expect("autoscaled sim");
    scenario_cost(
        &mut report,
        "autoscale",
        s_mean.events + s_peak.events + s_auto.events,
        ms_mean + ms_peak + ms_auto,
    );
    assert!(!s_mean.autoscaled && s_mean.scale_ups == 0, "fixed fleets never scale");
    report.metric("autoscale_offered_rps", cap_one * 2.4);
    report.metric("slo_attain_fixed_mean", s_mean.slo_attainment());
    report.metric("slo_attain_fixed_peak", s_peak.slo_attainment());
    report.metric("slo_attain_autoscaled", s_auto.slo_attainment());
    report.metric("scale_ups", s_auto.scale_ups as f64);
    report.metric("scale_downs", s_auto.scale_downs as f64);
    report.metric("wake_ms", s_auto.wake_ms);
    report.metric("wake_energy_mj", s_auto.wake_energy_mj);
    report.metric("scale_reaction_ms", s_auto.mean_reaction_ms);
    assert!(s_auto.scale_ups >= 1, "the burst must wake capacity at least once");
    assert!(
        s_auto.slo_attainment() >= s_mean.slo_attainment(),
        "acceptance: autoscaled {:.3} must reach at least the equal-mean-capacity \
         fixed fleet {:.3}",
        s_auto.slo_attainment(),
        s_mean.slo_attainment()
    );

    // ---- simulator hot path: events per wall-clock second -----------------
    section("serve — event-loop throughput (host side)");
    let iters = if smoke { 5 } else { 30 };
    let bench_arrivals =
        trace::generate(&ArrivalProcess::Poisson { rps: 400.0 }, 2_000.0, 11);
    // the engine's own event census (arrivals + ticks + every shard-local
    // pop), not just the arrival count — deterministic per seed, so every
    // iteration processes exactly this many
    let n_events = simulate_fleet(&fleet, &bench_arrivals, &cfg).unwrap().events as f64;
    assert!(n_events >= bench_arrivals.len() as f64, "every arrival is an event");
    let stats = bench("simulate_fleet (5 variants, 2s @ 400rps)", 2, iters, || {
        simulate_fleet(&fleet, &bench_arrivals, &cfg).unwrap()
    });
    let eps = n_events / (stats.mean_ms / 1e3);
    report.metric("sim_events_per_sec", eps);
    assert!(
        eps >= EVENTS_PER_SEC_FLOOR,
        "hot path: {eps:.0} events/s is below the {EVENTS_PER_SEC_FLOOR:.0} floor"
    );
    report.push(stats);

    // ---- streaming stress: million-request runs at constant memory --------
    section("serve — streaming stress (10^6 requests, O(1) telemetry)");
    // stationary Poisson at 0.7x the hqp variant's capacity: the queue
    // stays bounded, so the latency distribution's *support* — and with
    // it the histogram's occupied-bin footprint — is set by the workload,
    // not by how long it runs. The trace itself is never materialized
    // (ArrivalGen over an unbounded horizon, taken to the budget).
    let stress_big = if smoke { 10_000usize } else { 1_000_000 };
    let stress_small = 10_000usize;
    let stress_rate = cap_hqp * 0.7;
    let stress_cfg = ServeConfig { slo_ms, ..Default::default() };
    let stress_proc = ArrivalProcess::Poisson { rps: stress_rate };
    let run_stress = |n: usize| {
        simulate_fleet_stream(
            &hqp_fleet,
            trace::ArrivalGen::new(&stress_proc, f64::INFINITY, 23).take(n),
            &stress_cfg,
            Jobs::one(),
        )
        .expect("stress sim")
    };
    let s_small = run_stress(stress_small);
    let (s_big, ms_big) = time_once(|| run_stress(stress_big));
    assert_eq!(s_small.generated, stress_small as u64, "request budget must be exact");
    assert_eq!(s_big.generated, stress_big as u64, "request budget must be exact");
    scenario_cost(&mut report, "stress", s_big.events, ms_big);
    report.metric("stress_requests", s_big.generated as f64);
    report.metric("stress_slo_attain", s_big.slo_attainment());
    report.metric("stress_p99_ms", s_big.p99_ms);
    report.metric("stress_hist_bins", s_big.latency_hist.occupied_bins() as f64);
    report.metric("stress_peak_queue_depth", s_big.peak_queue_depth as f64);
    // the acceptance assertion: peak resident telemetry state must be
    // independent of the request count. 100x the requests may fill a few
    // more tail bins of the same distribution, never O(n) state — and the
    // absolute footprint stays a few KB of u64 counts
    let (bins_small, bins_big) =
        (s_small.latency_hist.occupied_bins(), s_big.latency_hist.occupied_bins());
    assert!(
        bins_big <= bins_small + 256 && bins_big <= 2048,
        "telemetry footprint must not scale with request count: \
         {bins_big} bins at {stress_big} requests vs {bins_small} at {stress_small}"
    );
    assert!(
        s_big.peak_queue_depth <= stress_cfg.queue_cap as u64,
        "admission control must bound the queue high-water mark"
    );

    // ---- multi-tenant admission: weighted-fair vs fifo under a flash crowd -
    section("serve — weighted-fair vs fifo tenant admission (flash crowd, hqp on nx)");
    // two classes on one hqp server: `gold` (weight 8, tight SLO) and
    // `free` (weight 1, loose SLO). tenant_of hands gold 8/9 of the
    // traffic; the flash crowd spikes to 5x capacity, so during every
    // spike the queue backs up and admission *order* decides who meets
    // its deadline. FIFO drains the backlog in arrival order — tight-SLO
    // gold requests expire behind loose-SLO free ones that arrived
    // first — while weighted-fair hands gold its 8/9 share of every
    // dequeue, so gold rides through the spike at the cost of free
    // requests that could afford to wait anyway.
    let b1 = hqp_fleet.servers[0].variants[0].batch1_ms();
    let tenant_spec = format!("gold:0.015:{:.3}:8,free:0.015:{:.3}:1", b1 * 3.0, b1 * 40.0);
    // fixed 4 s window even under --smoke: the asserted separation needs
    // the spikes (mean gap 700 ms) to actually arrive
    let crowd =
        trace::generate(&ArrivalProcess::parse("flash-crowd", cap_hqp).unwrap(), 4_000.0, 29);
    let tenant_cfg = |admit: AdmitPolicy| ServeConfig {
        slo_ms,
        tenants: parse_tenants(&tenant_spec).expect("tenant spec"),
        admit,
        ..Default::default()
    };
    let (s_fifo, ms_fifo) =
        time_once(|| simulate_fleet(&hqp_fleet, &crowd, &tenant_cfg(AdmitPolicy::Fifo)));
    let s_fifo = s_fifo.expect("fifo sim");
    let (s_wfq, ms_wfq) =
        time_once(|| simulate_fleet(&hqp_fleet, &crowd, &tenant_cfg(AdmitPolicy::WeightedFair)));
    let s_wfq = s_wfq.expect("weighted-fair sim");
    scenario_cost(&mut report, "multi_tenant", s_fifo.events + s_wfq.events, ms_fifo + ms_wfq);
    let gold_fifo = s_fifo.tenants[0].attainment();
    let gold_wfq = s_wfq.tenants[0].attainment();
    report.metric("tenant_offered_rps", cap_hqp);
    report.metric("slo_attain_gold_fifo", gold_fifo);
    report.metric("slo_attain_gold_wfq", gold_wfq);
    report.metric("slo_attain_free_fifo", s_fifo.tenants[1].attainment());
    report.metric("slo_attain_free_wfq", s_wfq.tenants[1].attainment());
    assert_eq!(s_fifo.tenants.len(), 2, "both classes must be censused");
    assert!(
        s_fifo.tenants[0].generated > s_fifo.tenants[1].generated,
        "weight-proportional assignment must hand gold the traffic majority"
    );
    assert!(
        gold_wfq >= gold_fifo,
        "acceptance: weighted-fair gold attainment {gold_wfq:.3} must reach at \
         least fifo's {gold_fifo:.3} under the flash crowd"
    );

    // ---- predictive control plane: prewake vs reactive detection ----------
    section("serve — predictive prewake vs queue-depth reaction (hqp on nx+nano)");
    // heterogeneous 3-server fleet (NX, Nano, NX), one awake; the
    // forecaster watches the arrival stream and starts wakes when the
    // look-ahead rate crosses committed capacity, so its reaction time is
    // the wake latency alone — queue-depth pays two consecutive high
    // ticks of detection hysteresis on top of the same wake. Idle power
    // is priced (1 W) and control ticks run through the drain on both
    // sides, so the energy books are comparable end to end.
    let het = reference_fleet(
        "resnet18",
        &[Device::xavier_nx(), Device::jetson_nano()],
        &["hqp"],
        8,
    )
    .expect("fleet")
    .replicate_to(3)
    .expect("het fleet");
    let pred_cfg = |p: ScalePolicy| ServeConfig {
        slo_ms: slo_auto,
        idle_watts: 1.0,
        scale_to_drain: true,
        autoscale: AutoscaleConfig {
            policy: p,
            interval_ms: 25.0,
            min_active: 1,
            max_active: 3,
            ..AutoscaleConfig::off()
        },
        ..Default::default()
    };
    // fixed 8 s window even under --smoke: the forecaster needs gaps to
    // earn confidence and the MMPP bursts must actually arrive
    let pburst =
        trace::generate(&ArrivalProcess::parse("mmpp", cap_hqp * 1.2).unwrap(), 8_000.0, 31);
    let (s_react, ms_react) =
        time_once(|| simulate_fleet(&het, &pburst, &pred_cfg(ScalePolicy::QueueDepth)));
    let s_react = s_react.expect("reactive sim");
    let (s_pred, ms_pred) =
        time_once(|| simulate_fleet(&het, &pburst, &pred_cfg(ScalePolicy::Predictive)));
    let s_pred = s_pred.expect("predictive sim");
    scenario_cost(
        &mut report,
        "predictive",
        s_react.events + s_pred.events,
        ms_react + ms_pred,
    );
    report.metric("predictive_offered_rps", cap_hqp * 1.2);
    report.metric("scale_reaction_ms_queue_depth", s_react.mean_reaction_ms);
    report.metric("scale_reaction_ms_predictive", s_pred.mean_reaction_ms);
    report.metric("prewakes", s_pred.prewakes as f64);
    report.metric("forecast_abs_err_pct", s_pred.forecast_abs_err_pct);
    assert!(
        s_react.scale_ups >= 1 && s_pred.scale_ups >= 1,
        "both controllers must wake capacity into the bursts"
    );
    assert!(s_pred.prewakes >= 1, "the forecaster must drive at least one prewake");
    assert!(
        s_pred.mean_reaction_ms < s_react.mean_reaction_ms,
        "acceptance: predictive reaction {:.1} ms must be strictly below \
         queue-depth's {:.1} ms",
        s_pred.mean_reaction_ms,
        s_react.mean_reaction_ms
    );

    // ---- predictive energy: diurnal tide, idle power priced ---------------
    section("serve — predictive vs reactive energy under a diurnal tide");
    // the diurnal period locks the forecaster's seasonal blend: prewakes
    // land before each crest and the early-sleep rule drains into each
    // trough, so the fleet meets at least the reactive attainment while
    // spending no more energy per SLO-met request
    let tide =
        trace::generate(&ArrivalProcess::parse("diurnal", cap_hqp * 1.1).unwrap(), 8_000.0, 37);
    let (s_rt, ms_rt) =
        time_once(|| simulate_fleet(&het, &tide, &pred_cfg(ScalePolicy::QueueDepth)));
    let s_rt = s_rt.expect("reactive tide sim");
    let (s_pt, ms_pt) =
        time_once(|| simulate_fleet(&het, &tide, &pred_cfg(ScalePolicy::Predictive)));
    let s_pt = s_pt.expect("predictive tide sim");
    scenario_cost(&mut report, "diurnal_tide", s_rt.events + s_pt.events, ms_rt + ms_pt);
    assert!(
        s_rt.slo_attained > 0 && s_pt.slo_attained > 0,
        "both runs must meet SLOs to compare energy per SLO-met request"
    );
    assert!(
        s_rt.idle_energy_mj > 0.0 && s_pt.idle_energy_mj > 0.0,
        "1 W of idle power over an 8 s tide must charge something"
    );
    let e_per_slo_react = s_rt.energy_mj / s_rt.slo_attained as f64;
    let e_per_slo_pred = s_pt.energy_mj / s_pt.slo_attained as f64;
    report.metric("slo_attain_tide_queue_depth", s_rt.slo_attainment());
    report.metric("slo_attain_tide_predictive", s_pt.slo_attainment());
    report.metric("idle_energy_mj_queue_depth", s_rt.idle_energy_mj);
    report.metric("idle_energy_mj_predictive", s_pt.idle_energy_mj);
    report.metric("energy_per_slo_met_queue_depth", e_per_slo_react);
    report.metric("energy_per_slo_met_predictive", e_per_slo_pred);
    assert!(
        s_pt.slo_attainment() >= s_rt.slo_attainment(),
        "acceptance: predictive attainment {:.3} must reach at least \
         reactive's {:.3} on the tide",
        s_pt.slo_attainment(),
        s_rt.slo_attainment()
    );
    assert!(
        e_per_slo_pred <= e_per_slo_react,
        "acceptance: predictive {:.2} mJ per SLO-met request must not exceed \
         reactive's {:.2} (wake + idle + swap included)",
        e_per_slo_pred,
        e_per_slo_react
    );

    // ---- joules-per-slo routing vs acc-fastest ----------------------------
    section("serve — joules-per-slo router vs acc-fastest (full fleet, matched load)");
    // same 5-variant fleet and saturating trace as the acc-fastest
    // scenario above: the energy-aware router spends its Δ_max budget on
    // the cheapest compliant engine instead of the most accurate one
    let jps_cfg = ServeConfig { slo_ms, policy: Policy::JoulesPerSlo, ..Default::default() };
    let (s_jps, ms_jps) = time_once(|| simulate_fleet(&fleet, &arrivals, &jps_cfg));
    let s_jps = s_jps.expect("joules-per-slo sim");
    scenario_cost(&mut report, "joules_per_slo", s_jps.events, ms_jps);
    assert!(
        s_fleet.slo_attained > 0 && s_jps.slo_attained > 0,
        "both routers must meet SLOs to compare energy per SLO-met request"
    );
    let e_per_slo_af = s_fleet.energy_mj / s_fleet.slo_attained as f64;
    let e_per_slo_jps = s_jps.energy_mj / s_jps.slo_attained as f64;
    report.metric("slo_attain_jps", s_jps.slo_attainment());
    report.metric("energy_per_slo_met_acc_fastest", e_per_slo_af);
    report.metric("energy_per_slo_met_jps", e_per_slo_jps);
    assert!(
        e_per_slo_jps <= e_per_slo_af,
        "acceptance: joules-per-slo {:.2} mJ per SLO-met request must not \
         exceed acc-fastest's {:.2}",
        e_per_slo_jps,
        e_per_slo_af
    );

    report.write_json("BENCH_serve.json").expect("write BENCH_serve.json");
    println!("\nwrote BENCH_serve.json");
}
