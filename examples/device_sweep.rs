//! Device heterogeneity sweep (paper §IV-A): the same compressed engines
//! priced across Jetson Nano (no INT8 units), Xavier NX (48 tensor cores)
//! and an idealized flat-rate accelerator — plus an ablation of the
//! TensorRT-substitute optimizations (fusion / dead-channel elim /
//! autotune) that shows where each millisecond goes.
//!
//! Pure deployment-model sweep: no PJRT execution, runs in milliseconds.
//!
//! ```bash
//! cargo run --release --example device_sweep
//! ```

use hqp::gopt::{optimize, OptimizeOptions};
use hqp::graph::{full_masks, Graph};
use hqp::hwsim::{simulate, Device};
use hqp::runtime::Workspace;

fn main() -> hqp::Result<()> {
    let ws = Workspace::open("artifacts")?;
    for model in ["mobilenetv3", "resnet18"] {
        let g = Graph::from_manifest(ws.manifest.model(model)?)?;
        let masks = full_masks(&g);
        // a representative HQP mask: drop 40 % of every group's filters
        let mut hqp_masks = masks.clone();
        for m in hqp_masks.iter_mut() {
            let kill = (m.len() as f64 * 0.4) as usize;
            for j in 0..kill {
                m[j] = false;
            }
        }

        println!("\n=== {model} ({:.1} MFLOPs dense) ===", g.dense_flops() as f64 / 1e6);
        println!(
            "{:<12} {:>11} {:>11} {:>11} {:>9}",
            "device", "fp32 ms", "int8 ms", "hqp ms", "hqp x"
        );
        for dev in Device::all() {
            let fp32 = simulate(&optimize(&g, &masks, &OptimizeOptions::fp32())?, &dev);
            let int8 = simulate(&optimize(&g, &masks, &OptimizeOptions::int8())?, &dev);
            let hqp = simulate(&optimize(&g, &hqp_masks, &OptimizeOptions::int8())?, &dev);
            println!(
                "{:<12} {:>11.4} {:>11.4} {:>11.4} {:>8.2}x   ({}% ops memory-bound fp32)",
                dev.name,
                fp32.latency_ms,
                int8.latency_ms,
                hqp.latency_ms,
                fp32.latency_ms / hqp.latency_ms,
                (fp32.memory_bound_frac * 100.0) as u32
            );
        }

        // optimizer ablation on Xavier NX
        let dev = Device::xavier_nx();
        let mut o_all = OptimizeOptions::int8();
        let mut o_nofuse = OptimizeOptions::int8();
        o_nofuse.fusion = false;
        let mut o_notune = OptimizeOptions::int8();
        o_notune.autotune = false;
        let all = simulate(&optimize(&g, &hqp_masks, &o_all)?, &dev);
        let nofuse = simulate(&optimize(&g, &hqp_masks, &o_nofuse)?, &dev);
        let notune = simulate(&optimize(&g, &hqp_masks, &o_notune)?, &dev);
        o_all.fusion = false;
        o_all.autotune = false;
        let none = simulate(&optimize(&g, &hqp_masks, &o_all)?, &dev);
        println!("optimizer ablation on xavier-nx (hqp engine):");
        println!("  all passes        {:>9.4} ms", all.latency_ms);
        println!("  - fusion          {:>9.4} ms ({:+.1}%)", nofuse.latency_ms,
                 (nofuse.latency_ms / all.latency_ms - 1.0) * 100.0);
        println!("  - autotune        {:>9.4} ms ({:+.1}%)", notune.latency_ms,
                 (notune.latency_ms / all.latency_ms - 1.0) * 100.0);
        println!("  - both            {:>9.4} ms ({:+.1}%)", none.latency_ms,
                 (none.latency_ms / all.latency_ms - 1.0) * 100.0);
    }
    Ok(())
}
