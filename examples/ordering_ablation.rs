//! §V-B ORDERING ABLATION — the schedule API's payoff experiment.
//!
//! The paper *argues* that ordering matters: pruning pre-conditions the
//! model (removing the outlier filters that inflate the dynamic range R)
//! so PTQ survives, while quantize-first locks calibration to the dense
//! model. This example makes that claim runnable: it compares
//! `prune >> ptq` (the paper's HQP ordering) against `ptq >> prune`
//! (quantize-first — inexpressible under the pre-schedule closed method
//! enum) on ResNet-18, same config, same session.
//!
//! ```bash
//! cargo run --release --example ordering_ablation            # paper δ = 1 %
//! cargo run --release --example ordering_ablation -- --fast  # coarse δ
//! ```

use hqp::hqp::{HqpConfig, Schedule};
use hqp::runtime::{Session, Workspace};

fn main() -> hqp::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let ws = Workspace::open("artifacts")?;
    let cfg = HqpConfig {
        delta_step_frac: if fast { 0.05 } else { 0.01 },
        ..Default::default()
    };

    // one shared session: the baseline sweep is memoized, the parameter
    // buffer cache carries across both schedules
    let mut sess = Session::new(&ws, "resnet18")?;
    println!(
        "{:<14} {:>8} {:>8} {:>8} {:>10}",
        "schedule", "drop %", "θ %", "regime", "Δmax ok"
    );
    for spec in ["prune >> ptq", "ptq >> prune"] {
        let sched = Schedule::parse(spec)?;
        let t0 = std::time::Instant::now();
        let o = sched.run(&mut sess, &cfg)?;
        println!(
            "{:<14} {:>8.2} {:>8.1} {:>8} {:>10}   ({:.1}s)",
            o.method,
            o.acc_drop() * 100.0,
            o.sparsity * 100.0,
            format!("{:?}", o.regime).to_lowercase(),
            if o.compliant(cfg.delta_max) { "yes" } else { "NO" },
            t0.elapsed().as_secs_f64(),
        );
    }
    println!(
        "\nquantize-first prunes an already-projected model against scales \
         calibrated on the dense one — the §V-B conflict, now measurable."
    );
    Ok(())
}
