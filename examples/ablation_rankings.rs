//! Ranking ablation (paper §II-A's critique, measured): how far can each
//! saliency metric prune under the same Δ_max before Algorithm 1 stops?
//!
//! Fisher (HQP) vs L1/L2 magnitude vs BN-γ vs random — the maximal
//! compliant sparsity is the figure of merit (higher = better ranking).
//!
//! ```bash
//! cargo run --release --example ablation_rankings    # ~5-10 min
//! ```

use hqp::hqp::{prune, sensitivity, HqpConfig, RankingMethod};
use hqp::runtime::{Session, Workspace};

fn main() -> hqp::Result<()> {
    let ws = Workspace::open("artifacts")?;
    for model in ["resnet18", "mobilenetv3"] {
        let mut sess = Session::new(&ws, model)?;
        let baseline = sess.baseline.clone();
        let base_acc = sess.accuracy(&baseline, "val")?;
        let cfg = HqpConfig { delta_step_frac: 0.05, ..Default::default() };
        println!("\n=== {model} (baseline {base_acc:.4}, Δ_max {:.1}%) ===", cfg.delta_max * 100.0);
        println!(
            "{:<10} {:>14} {:>12} {:>10}",
            "ranking", "max θ compliant", "final acc", "steps"
        );
        for method in [
            RankingMethod::Fisher,
            RankingMethod::MagnitudeL1,
            RankingMethod::MagnitudeL2,
            RankingMethod::BnGamma,
            RankingMethod::Random(42),
        ] {
            let sal = sensitivity::compute(&mut sess, &baseline, method, cfg.calib_samples)?;
            let res = prune::conditional_prune(&mut sess, &baseline, base_acc, &sal, &cfg)?;
            println!(
                "{:<10} {:>13.1}% {:>12.4} {:>10}",
                method.name(),
                res.sparsity * 100.0,
                res.accuracy,
                res.trace.steps.len()
            );
        }
    }
    Ok(())
}
