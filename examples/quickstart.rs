//! Quickstart: open the workspace, run one HQP pipeline, print the table
//! row — the 20-line tour of the public API.
//!
//! ```bash
//! make artifacts            # once: trains models + AOT-lowers the HLO
//! cargo run --release --example quickstart
//! ```

use hqp::graph::Graph;
use hqp::hqp::{deploy, run_hqp, HqpConfig};
use hqp::hwsim::Device;
use hqp::runtime::{Session, Workspace};

fn main() -> hqp::Result<()> {
    // 1. open the AOT artifacts (HLO text + weights + datasets + manifest)
    let ws = Workspace::open("artifacts")?;
    println!("PJRT platform: {}", ws.platform());

    // 2. bind a model and run the paper's pipeline:
    //    Fisher sensitivity -> Algorithm-1 conditional pruning (Δ_max=1.5%)
    //    -> KL-calibrated INT8 PTQ. A coarser δ keeps the demo fast.
    let mut sess = Session::new(&ws, "mobilenetv3")?;
    let cfg = HqpConfig { delta_step_frac: 0.05, ..Default::default() };
    let outcome = run_hqp(&mut sess, &cfg)?;
    println!(
        "HQP: sparsity θ={:.0}%, accuracy {:.4} (baseline {:.4}, drop {:.2}%)",
        outcome.sparsity * 100.0,
        outcome.accuracy,
        outcome.baseline_acc,
        outcome.acc_drop() * 100.0
    );

    // 3. deploy onto the simulated Jetson Xavier NX and print the row
    let graph = Graph::from_manifest(&sess.mm)?;
    let row = deploy::report(&graph, &outcome, &Device::xavier_nx(), cfg.delta_max)?;
    println!(
        "deployed on {}: {:.3} ms ({:.2}x speedup), size -{:.0}%, {} Δ-compliant",
        row.device,
        row.latency_ms,
        row.speedup,
        row.size_reduction * 100.0,
        if row.compliant { "is" } else { "is NOT" }
    );
    Ok(())
}
