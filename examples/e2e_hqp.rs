//! END-TO-END DRIVER (the repro harness's mandated full-system workload):
//! runs the complete HQP evaluation — both models, all four methods at
//! paper parameters, both Jetson devices — through every layer of the
//! stack (PJRT-executed L2 graphs with L1 Pallas kernels, coordinated by
//! the L3 pipeline, deployed through gopt onto hwsim), and prints the
//! paper-vs-measured comparison recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example e2e_hqp            # ~10-20 min single-core
//! cargo run --release --example e2e_hqp -- --fast  # coarse δ, ~3 min
//! ```

use hqp::coordinator::{run_method, MethodSpec};
use hqp::hqp::HqpConfig;
use hqp::hwsim::Device;
use hqp::report;
use hqp::runtime::Workspace;

/// Paper numbers (Tables I & II, Xavier NX) for the shape comparison.
/// (method, speedup, acc_drop_pct, sparsity_pct)
const PAPER_T1: &[(&str, f64, f64, f64)] = &[
    ("baseline", 1.00, 0.0, 0.0),
    ("q8-only", 1.58, 1.2, 0.0),
    ("p50-only", 1.35, 1.8, 50.0),
    ("hqp", 3.12, 1.4, 45.0),
];
const PAPER_T2: &[(&str, f64, f64, f64)] = &[
    ("baseline", 1.00, 0.0, 0.0),
    ("q8-only", 1.55, 1.9, 0.0),
    ("hqp", 2.51, 1.3, 35.0),
];

fn main() -> hqp::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let ws = Workspace::open("artifacts")?;
    let cfg = HqpConfig {
        delta_step_frac: if fast { 0.05 } else { 0.01 },
        ..Default::default()
    };
    let devices = Device::all();
    let force = std::env::args().any(|a| a == "--force");

    for (model, paper) in [("mobilenetv3", PAPER_T1), ("resnet18", PAPER_T2)] {
        println!("\n################ {model} ################");
        let mut rows = Vec::new();
        for spec in [
            MethodSpec::Baseline,
            MethodSpec::Q8Only,
            MethodSpec::PruneOnly(50),
            MethodSpec::Hqp,
        ] {
            let t0 = std::time::Instant::now();
            let r = run_method(&ws, model, spec, &cfg, &devices, force)?;
            println!(
                "  ran {:?} in {:.1}s ({} device rows)",
                spec,
                t0.elapsed().as_secs_f64(),
                r.len()
            );
            rows.extend(r);
        }

        for dev in [Device::xavier_nx(), Device::jetson_nano()] {
            let reports = hqp::coordinator::experiments::reports_for_device(&rows, &dev.name);
            println!(
                "\n{}",
                report::method_table(&format!("{model} on {}", dev.name), &reports)
            );
        }

        // paper-vs-measured shape comparison (Xavier NX)
        println!("paper-vs-measured (Xavier NX):");
        println!(
            "  {:<10} {:>14} {:>14} {:>16} {:>14}",
            "method", "speedup(paper)", "speedup(ours)", "drop%(paper/ours)", "θ%(paper/ours)"
        );
        let nx = hqp::coordinator::experiments::reports_for_device(&rows, "xavier-nx");
        for (name, p_speed, p_drop, p_theta) in paper {
            if let Some(r) = nx.iter().find(|r| r.method == *name) {
                println!(
                    "  {:<10} {:>14.2} {:>14.2} {:>8.1}/{:<7.2} {:>7.0}/{:<6.0}",
                    name,
                    p_speed,
                    r.speedup,
                    p_drop,
                    r.acc_drop * 100.0,
                    p_theta,
                    r.sparsity * 100.0
                );
            }
        }

        // conditional-loop trajectory for HQP (the quality-guarantee story)
        if let Some(hqp_row) = rows.iter().find(|r| {
            r.report.method == "hqp" && r.report.device == "xavier-nx" && !r.trace.is_empty()
        }) {
            println!("\nAlgorithm 1 trajectory ({model}):");
            for (s, a, ok) in &hqp_row.trace {
                println!(
                    "  θ={:>5.1}%  val acc {:.4}  {}",
                    s * 100.0,
                    a,
                    if *ok { "accepted" } else { "REJECTED -> stop" }
                );
            }
        }
    }
    println!("\nE2E complete. Results cached under artifacts/results/.");
    Ok(())
}
