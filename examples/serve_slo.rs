//! Request rate vs SLO attainment: the serving-level view of the paper's
//! compression tradeoff. Each HQP variant is loaded alone on a Xavier NX
//! and swept across offered loads; the knee of each curve is the load
//! where that engine stops meeting its SLO — compression moves the knee.
//!
//! Pure deployment-model sweep (reference profiles, no PJRT, no
//! artifacts), runs in well under a second:
//!
//! ```bash
//! cargo run --release --example serve_slo
//! ```

use hqp::hwsim::Device;
use hqp::serve::{reference_fleet, simulate_fleet, trace, ArrivalProcess, Policy, ServeConfig};

fn main() -> hqp::Result<()> {
    let dev = Device::xavier_nx();
    let model = "resnet18";
    let methods = ["baseline", "q8", "hqp"];
    let rates = [50.0, 100.0, 200.0, 400.0, 800.0, 1600.0, 3200.0];
    let cfg = ServeConfig {
        slo_ms: 25.0,
        policy: Policy::AccFastest,
        ..Default::default()
    };

    // one single-variant fleet per method; the sweep only varies the rate
    let fleets = methods
        .iter()
        .map(|&m| reference_fleet(model, &[dev.clone()], &[m], cfg.max_batch))
        .collect::<hqp::Result<Vec<_>>>()?;

    println!(
        "SLO attainment (%) by offered load — {model} on {}, slo {} ms, poisson, seed 42",
        dev.name, cfg.slo_ms
    );
    print!("{:<10}", "rps");
    for m in methods {
        print!(" {m:>9}");
    }
    println!();
    for &rps in &rates {
        let arrivals = trace::generate(&ArrivalProcess::Poisson { rps }, 5_000.0, 42);
        print!("{rps:<10.0}");
        for fleet in &fleets {
            let s = simulate_fleet(fleet, &arrivals, &cfg)?;
            print!(" {:>8.1}%", s.slo_attainment() * 100.0);
        }
        println!();
    }

    println!();
    for (m, fleet) in methods.iter().zip(&fleets) {
        let v = &fleet.servers[0].variants[0];
        println!(
            "{m:<9} batch-1 {:>7.3} ms   roofline capacity {:>6.0} rps   acc drop {:.2}%",
            v.batch1_ms(),
            v.capacity_rps(),
            v.acc_drop * 100.0
        );
    }
    println!(
        "\nthe knee of each curve tracks the variant's capacity: HQP serves the same\n\
         SLO at roughly an order of magnitude higher load than the fp32 baseline\n\
         (the serving-level analogue of the paper's 3.12x single-inference speedup)."
    );
    Ok(())
}
