//! Mixed-precision extension (paper §VI-A, future work — implemented):
//! drive per-group precision (INT4 / INT8 / FP16) from the Fisher
//! sensitivity S and compare the deployed engines.
//!
//! ```bash
//! cargo run --release --example mixed_precision
//! ```

use hqp::gopt::{optimize, OptimizeOptions};
use hqp::graph::{full_masks, Graph};
use hqp::hqp::{mixed, run_hqp, HqpConfig};
use hqp::hwsim::{simulate, Device, Precision};
use hqp::runtime::{Session, Workspace};

fn main() -> hqp::Result<()> {
    let ws = Workspace::open("artifacts")?;
    let mut sess = Session::new(&ws, "mobilenetv3")?;
    let cfg = HqpConfig { delta_step_frac: 0.05, ..Default::default() };

    println!("running HQP to obtain masks + Fisher scores...");
    let outcome = run_hqp(&mut sess, &cfg)?;
    let scores = outcome.saliency_scores.clone().expect("fisher scores");

    let graph = Graph::from_manifest(&sess.mm)?;
    let dev = Device::xavier_nx();
    let base = simulate(
        &optimize(&graph, &full_masks(&graph), &OptimizeOptions::fp32())?,
        &dev,
    );

    println!(
        "\n{:<34} {:>9} {:>9} {:>10}",
        "policy", "ms", "speedup", "weights KB"
    );
    let mut show = |label: &str, opts: &OptimizeOptions| -> hqp::Result<()> {
        let eng = optimize(&graph, &outcome.masks, opts)?;
        let sim = simulate(&eng, &dev);
        println!(
            "{:<34} {:>9.4} {:>8.2}x {:>10.1}",
            label,
            sim.latency_ms,
            base.latency_ms / sim.latency_ms,
            eng.weight_bytes as f64 / 1024.0
        );
        Ok(())
    };

    show("uniform int8 (paper HQP)", &OptimizeOptions::int8())?;

    for (label, policy) in [
        (
            "mixed: int4<=q25, fp16>=q90 (default)",
            mixed::MixedPolicy::default(),
        ),
        (
            "mixed aggressive: int4<=q50",
            mixed::MixedPolicy { int4_quantile: 0.5, fp16_quantile: 0.95 },
        ),
        (
            "mixed conservative: int4<=q10",
            mixed::MixedPolicy { int4_quantile: 0.1, fp16_quantile: 0.75 },
        ),
    ] {
        let plan = mixed::plan(&scores, &sess.mm.groups, policy);
        let (mut n4, mut n16) = (0, 0);
        for p in plan.per_group.values() {
            match p {
                Precision::Int4 => n4 += 1,
                Precision::Fp16 => n16 += 1,
                _ => {}
            }
        }
        let mut opts = OptimizeOptions::int8();
        opts.precision = plan;
        show(&format!("{label} [{n4}xI4,{n16}xF16]"), &opts)?;
    }

    println!(
        "\nNote: mixed-precision *accuracy* requires INT4-grid weight\n\
         projection on the low-S groups; this example reports the deployed\n\
         latency/storage trade-off the S-guided plan unlocks (the paper\n\
         frames exactly this as §VI-A future work)."
    );
    Ok(())
}
