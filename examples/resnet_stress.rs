//! ResNet-18 PTQ stress test (paper §V-D): residual connections vs INT8.
//!
//! Reproduces the pruning-quantization-conflict experiment in isolation:
//! how does the quantized accuracy respond to (a) the calibration method
//! and (b) pruning pre-conditioning? Prints the dynamic-range (threshold)
//! statistics that drive the paper's §II-C step-size argument.
//!
//! ```bash
//! cargo run --release --example resnet_stress
//! ```

use hqp::hqp::{prune, ptq, sensitivity, HqpConfig, RankingMethod};
use hqp::quant::CalibMethod;
use hqp::runtime::{Session, Workspace};

fn main() -> hqp::Result<()> {
    let ws = Workspace::open("artifacts")?;
    let mut sess = Session::new(&ws, "resnet18")?;
    let baseline = sess.baseline.clone();
    let base_acc = sess.accuracy(&baseline, "val")?;
    println!("ResNet-18 baseline FP32 accuracy: {base_acc:.4}\n");

    // --- (a) direct PTQ under each calibration method -------------------
    println!("Q8-only (no pruning) by calibration method:");
    for method in [CalibMethod::MinMax, CalibMethod::Percentile, CalibMethod::Kl] {
        let cfg = HqpConfig { calib_method: method, ..Default::default() };
        let r = ptq::quantize(&mut sess, &baseline, &cfg)?;
        let tmax = r.thresholds.iter().cloned().fold(0f32, f32::max);
        let tmean = r.thresholds.iter().sum::<f32>() / r.thresholds.len() as f32;
        println!(
            "  {:<12} acc {:.4} (drop {:+.2}%)   thresholds: mean {:.3}, max {:.3}",
            format!("{method:?}"),
            r.accuracy,
            (base_acc - r.accuracy) * 100.0,
            tmean,
            tmax
        );
    }

    // --- (b) pruning pre-conditioning at increasing sparsity -------------
    println!("\nPrune-then-quantize (KL calibration), fisher ranking:");
    let cfg = HqpConfig::default();
    let sal = sensitivity::compute(&mut sess, &baseline, RankingMethod::Fisher, 256)?;
    for theta in [0.0, 0.1, 0.2, 0.3, 0.4] {
        let pruned = prune::prune_to_sparsity(&mut sess, &baseline, &sal, theta)?;
        let q = ptq::quantize(&mut sess, &pruned.params, &cfg)?;
        let wmax: f32 = pruned
            .params
            .tensors()
            .iter()
            .map(|t| t.absmax())
            .fold(0f32, f32::max);
        println!(
            "  θ={:>3.0}%  fp32-sparse acc {:.4}  ->  int8 acc {:.4} (total drop {:+.2}%)   max|W| {:.3}",
            theta * 100.0,
            pruned.accuracy,
            q.accuracy,
            (base_acc - q.accuracy) * 100.0,
            wmax
        );
    }

    println!(
        "\nInterpretation: the paper's §V-D claim is that moderate S-guided\n\
         sparsity stabilizes the PTQ step on residual architectures; compare\n\
         the int8 column against θ=0 to see the measured effect on this\n\
         workload (EXPERIMENTS.md discusses where it matches and where it\n\
         deviates)."
    );
    Ok(())
}
