"""Synthetic dataset: determinism, class balance, value ranges and the
fine-grained class structure the HQP evaluation depends on."""

import numpy as np

from compile import datagen


def test_split_reproducible_bit_for_bit():
    x1, y1 = datagen.make_split(64, seed=123)
    x2, y2 = datagen.make_split(64, seed=123)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_different_seeds_differ():
    x1, _ = datagen.make_split(16, seed=1)
    x2, _ = datagen.make_split(16, seed=2)
    assert not np.allclose(x1, x2)


def test_value_range_and_dtype():
    x, y = datagen.make_split(128, seed=9)
    assert x.dtype == np.float32
    assert y.dtype == np.int32
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert x.shape == (128, 32, 32, 3)


def test_labels_cover_all_classes():
    _, y = datagen.make_split(1000, seed=5)
    assert set(np.unique(y)) == set(range(10))
    # roughly balanced (uniform sampling): no class under 5%
    counts = np.bincount(y, minlength=10)
    assert counts.min() > 50


def test_label_noise_reproducible_and_configured():
    # NOTE: the generator draws the noise uniform lazily (only when
    # label_noise > 0), so streams with different noise settings are not
    # comparable sample-by-sample; we pin reproducibility at fixed settings
    # and the canonical split configuration instead.
    _, y1 = datagen.make_split(500, seed=7, label_noise=0.5)
    _, y2 = datagen.make_split(500, seed=7, label_noise=0.5)
    np.testing.assert_array_equal(y1, y2)
    assert datagen.SPLITS["train"]["label_noise"] > 0.0
    for split in ["calib", "val", "test"]:
        assert datagen.SPLITS[split]["label_noise"] == 0.0


def test_paired_classes_differ_only_in_texture_statistics():
    """Classes k and k+5 share shape+palette; their pixel-level stats
    should be close while the stripe frequency separates them — verify the
    dataset actually encodes the fine-grained signal."""
    rng = np.random.Generator(np.random.Philox(key=11))
    a = np.stack([datagen.make_image(1, rng) for _ in range(32)])
    rng = np.random.Generator(np.random.Philox(key=11))
    b = np.stack([datagen.make_image(6, rng) for _ in range(32)])
    # same palette family -> similar global means
    assert abs(a.mean() - b.mean()) < 0.1
    # different stripe frequency -> different high-frequency energy
    def hf_energy(imgs):
        dx = np.diff(imgs, axis=2)
        return float(np.mean(dx * dx))
    assert abs(hf_energy(a) - hf_energy(b)) > 1e-4


def test_canonical_splits_configured():
    for name in ["train", "calib", "val", "test"]:
        cfg = datagen.SPLITS[name]
        assert cfg["n"] >= 1024
    assert datagen.SPLITS["calib"]["label_noise"] == 0.0
    assert datagen.SPLITS["val"]["label_noise"] == 0.0
    # distinct seeds -> disjoint-ish splits
    seeds = [datagen.SPLITS[n]["seed"] for n in datagen.SPLITS]
    assert len(set(seeds)) == len(seeds)
