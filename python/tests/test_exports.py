"""Exported function set: the (params..., extras) -> outputs contracts that
the Rust runtime executes blind. Fisher/absmax/hist semantics are verified
against independent jnp recomputations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import models as zoo
from compile.layers import HIST_BINS

NAME = "resnet18"  # cheaper of the two; mobilenetv3 covered in test_models


@pytest.fixture(scope="module")
def bundle():
    net = M.trace(NAME)
    params, order = zoo.get(NAME).init_params(seed=3)
    plist = M.params_to_list(params, order)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0.4, 0.25, (8, 32, 32, 3)).clip(0, 1), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, (8,)), jnp.int32)
    return net, params, order, plist, x, y


def test_absmax_matches_direct_recomputation(bundle):
    net, params, order, plist, x, _ = bundle
    mx, logits = jax.jit(M.make_act_absmax(NAME, order))(plist, x)
    assert mx.shape == (len(net.taps),)
    assert logits.shape == (8, 10)
    # recompute tap 0 (= stem conv input = x itself)
    np.testing.assert_allclose(float(mx[0]), float(jnp.max(jnp.abs(x))), rtol=1e-6)
    assert bool(jnp.all(mx > 0))


def test_hist_mass_equals_element_counts(bundle):
    net, params, order, plist, x, _ = bundle
    mx, _ = jax.jit(M.make_act_absmax(NAME, order))(plist, x)
    hist, _ = jax.jit(M.make_act_hist(NAME, order))(plist, x, mx)
    assert hist.shape == (len(net.taps), HIST_BINS)
    # the mass of each tap's histogram equals the number of activations
    for i, tap in enumerate(net.taps):
        expect = x.shape[0] * int(np.prod(tap.shape[1:]))
        assert int(hist[i].sum()) == expect, tap.op_name


def test_hist_respects_ranges(bundle):
    net, params, order, plist, x, _ = bundle
    mx, _ = jax.jit(M.make_act_absmax(NAME, order))(plist, x)
    # halve the ranges: mass must pile into the top bin (clamped), total
    # mass must be conserved
    hist_full, _ = jax.jit(M.make_act_hist(NAME, order))(plist, x, mx)
    hist_half, _ = jax.jit(M.make_act_hist(NAME, order))(plist, x, mx / 2)
    np.testing.assert_allclose(hist_full.sum(axis=1), hist_half.sum(axis=1))
    assert float(hist_half[:, -1].sum()) >= float(hist_full[:, -1].sum())


def test_fisher_matches_manual_per_sample_grads(bundle):
    net, params, order, plist, x, y = bundle
    s, = jax.jit(M.make_fisher_gradsq(NAME, order, net.groups))(plist, x, y)
    assert s.shape == (sum(g.size for g in net.groups),)
    assert bool(jnp.all(s >= 0))

    # manual recomputation for ONE group on a 2-sample microbatch
    g0 = net.groups[0]

    def loss_i(params_dict, xi, yi):
        from compile.layers import Net
        net2 = Net("apply", params=params_dict)
        logits = zoo.get(NAME).forward(net2, xi[None])[0]
        return -jax.nn.log_softmax(logits)[yi]

    total = np.zeros(g0.size, np.float32)
    for i in range(2):
        g = jax.grad(lambda p: loss_i(p, x[i], y[i]))(params)[g0.producer_param]
        gw = np.moveaxis(np.asarray(g), g0.producer_axis, 0).reshape(g0.size, -1)
        total += (gw * gw).sum(axis=1)

    s2, = jax.jit(M.make_fisher_gradsq(NAME, order, net.groups))(plist, x[:2], y[:2])
    np.testing.assert_allclose(s2[: g0.size], total, rtol=2e-3, atol=1e-7)


def test_fisher_zero_for_dead_filter(bundle):
    net, params, order, plist, x, y = bundle
    # zero out filter 0 of group 1 completely (producer + bn) -> its
    # gradient-square wrt the producer slice need not be zero in general,
    # BUT a filter whose downstream bn gamma/beta are zero receives no
    # gradient through the bn, so S should collapse to ~0 for conv groups.
    g = net.groups[1]
    masked = dict(params)
    for pname, axis in g.members:
        arr = np.asarray(masked[pname]).copy()
        sl = [slice(None)] * arr.ndim
        sl[axis] = 0
        arr[tuple(sl)] = 0.0
        masked[pname] = jnp.asarray(arr)
    s, = jax.jit(M.make_fisher_gradsq(NAME, order, net.groups))(
        M.params_to_list(masked, order), x, y
    )
    val = float(s[g.offset])
    others = float(jnp.sum(s[g.offset : g.offset + g.size]))
    assert val < 1e-10 * max(others, 1e-3) + 1e-8, f"masked filter S={val}"


def test_train_loss_decreases_one_step(bundle):
    net, params, order, plist, x, y = bundle
    loss_fn = M.make_train_loss(NAME, order)
    trainable = {n: v for n, v in params.items() if not n.endswith((".mean", ".var"))}
    stats = {n: v for n, v in params.items() if n.endswith((".mean", ".var"))}
    (l0, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(trainable, stats, x, y)
    stepped = jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, trainable, grads)
    l1, _ = loss_fn(stepped, stats, x, y)
    assert float(l1) < float(l0)


def test_accuracy_helper():
    logits = jnp.asarray([[1.0, 0.0], [0.0, 1.0], [1.0, 0.0]])
    y = jnp.asarray([0, 1, 1])
    assert float(M.accuracy(logits, y)) == pytest.approx(2 / 3)
