"""AOT pipeline: HLO-text lowering invariants + manifest coherence.

These tests lower small functions in-process (cheap) and, when
artifacts/ already exists (post `make artifacts`), validate the shipped
manifest against a fresh trace."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrippable_header():
    lowered = jax.jit(lambda x: (x * 2.0 + 1.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    # HLO text module header + an entry computation — the two things
    # HloModuleProto::from_text_file needs
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: root is a tuple
    assert "tuple(" in text.replace(" ", "")[:20000] or "(f32[4]" in text


def test_spec_helper():
    s = aot._spec([2, 3], "f32")
    assert s.shape == (2, 3) and s.dtype == jnp.float32
    s = aot._spec([7], "i32")
    assert s.dtype == jnp.int32


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
class TestShippedManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_models_present_with_artifacts(self, manifest):
        for name in ["mobilenetv3", "resnet18"]:
            m = manifest["models"][name]
            for fn in ["eval", "fisher", "absmax", "hist", "quant_eval"]:
                path = os.path.join(ART, m["artifacts"][fn]["file"])
                assert os.path.exists(path), path
                assert os.path.getsize(path) > 1000

    def test_manifest_matches_fresh_trace(self, manifest):
        for name in ["mobilenetv3", "resnet18"]:
            m = manifest["models"][name]
            net = M.trace(name)
            assert [p["name"] for p in m["param_order"]] == net.param_order
            assert len(m["groups"]) == len(net.groups)
            for gm, gt in zip(m["groups"], net.groups):
                assert gm["size"] == gt.size
                assert gm["offset"] == gt.offset
                assert gm["producer"] == gt.producer_param
            assert len(m["taps"]) == len(net.taps)
            assert len(m["ops"]) == len(net.ops)

    def test_weights_complete(self, manifest):
        for name in ["mobilenetv3", "resnet18"]:
            m = manifest["models"][name]
            wdir = os.path.join(ART, m["weights_dir"])
            assert len(os.listdir(wdir)) == len(m["param_order"])

    def test_data_splits_exist(self, manifest):
        for split, d in manifest["data"].items():
            assert os.path.exists(os.path.join(ART, d["x"])), split
            assert os.path.exists(os.path.join(ART, d["y"])), split

    def test_baseline_accuracy_recorded_sane(self, manifest):
        for name in ["mobilenetv3", "resnet18"]:
            acc = manifest["models"][name]["baseline_val_acc"]
            assert 0.85 < acc <= 1.0, f"{name}: {acc}"
