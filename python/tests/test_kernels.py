"""L1 Pallas kernels vs pure-jnp oracles — THE core correctness signal.

hypothesis sweeps shapes, block sizes, scales and magnitudes; every case
asserts allclose against ref.py. interpret=True keeps the kernels
executable on CPU (same lowering the AOT artifacts embed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fisher import fisher_accumulate
from compile.kernels.qmatmul import mxu_utilization, qmatmul, vmem_footprint_bytes
from compile.kernels.ref import fisher_ref, qmatmul_ref, quantize_sym

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _grid_weights(rng, k, n, scale=0.05):
    """Weights already on an int8 grid (the qmatmul contract)."""
    codes = rng.integers(-127, 128, size=(k, n)).astype(np.float32)
    return jnp.asarray(codes * scale)


class TestQmatmul:
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 160),
        n=st.integers(1, 96),
        bm=st.sampled_from([8, 32, 128]),
        bn=st.sampled_from([8, 32, 128]),
        bk=st.sampled_from([8, 32, 128]),
        sx=st.floats(1e-3, 0.5),
    )
    def test_matches_ref_across_shapes_and_blocks(self, m, k, n, bm, bn, bk, sx):
        rng = np.random.default_rng(m * 1000 + k * 10 + n)
        x = _rand(rng, m, k)
        w = _grid_weights(rng, k, n)
        sxa = jnp.asarray([sx], jnp.float32)
        got = qmatmul(x, w, sxa, bm=bm, bn=bn, bk=bk)
        want = qmatmul_ref(x, w, jnp.float32(sx))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_zero_scale_guard_not_needed_but_tiny_scale_exact(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, 16, 16)
        w = _grid_weights(rng, 16, 16)
        sx = jnp.asarray([1e-6], jnp.float32)
        got = qmatmul(x, w, sx)
        want = qmatmul_ref(x, w, sx[0])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_saturation_clips_to_pm127(self):
        # inputs far beyond the grid must saturate identically to ref
        x = jnp.full((4, 4), 1e6, jnp.float32)
        w = jnp.eye(4, dtype=jnp.float32)
        sx = jnp.asarray([0.1], jnp.float32)
        got = qmatmul(x, w, sx)
        np.testing.assert_allclose(got, jnp.full((4, 4), 12.7) @ w, rtol=1e-6)

    def test_jit_and_grad_through_kernel(self):
        # quant_eval lowers through jit; make sure that path is stable
        rng = np.random.default_rng(1)
        x = _rand(rng, 32, 24)
        w = _grid_weights(rng, 24, 8)
        sx = jnp.asarray([0.05], jnp.float32)
        f = jax.jit(lambda a: qmatmul(a, w, sx).sum())
        assert np.isfinite(float(f(x)))

    def test_vmem_footprint_and_utilization_helpers(self):
        assert vmem_footprint_bytes(128, 128, 128) == 4 * 3 * 128 * 128
        assert mxu_utilization(128, 128, 128, 128, 128, 128) == 1.0
        u = mxu_utilization(100, 100, 100, 128, 128, 128)
        assert 0 < u < 1

    def test_quantize_sym_round_half_even(self):
        # jnp.round is banker's rounding; rust mirrors it — pin it here
        xs = jnp.asarray([0.5, 1.5, 2.5, -0.5, -1.5], jnp.float32)
        got = quantize_sym(xs, 1.0)
        np.testing.assert_array_equal(got, [0.0, 2.0, 2.0, 0.0, -2.0])


class TestFisher:
    @given(
        b=st.integers(1, 8),
        f=st.integers(1, 300),
        e=st.integers(1, 32),
        bf=st.sampled_from([16, 64, 128]),
    )
    def test_matches_ref(self, b, f, e, bf):
        rng = np.random.default_rng(b * 7 + f)
        g = _rand(rng, b, f, e)
        got = fisher_accumulate(g, bf=bf)
        want = fisher_ref(g)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_nonnegative_and_zero_on_zero(self):
        g = jnp.zeros((4, 10, 3), jnp.float32)
        assert float(fisher_accumulate(g).sum()) == 0.0
        rng = np.random.default_rng(3)
        g = _rand(rng, 4, 10, 3)
        assert float(fisher_accumulate(g).min()) >= 0.0

    def test_scaling_quadratic(self):
        rng = np.random.default_rng(5)
        g = _rand(rng, 2, 6, 4)
        s1 = fisher_accumulate(g)
        s2 = fisher_accumulate(2.0 * g)
        np.testing.assert_allclose(s2, 4.0 * s1, rtol=1e-5)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (127, 129, 63), (256, 128, 10)])
def test_qmatmul_edge_shapes(m, k, n):
    rng = np.random.default_rng(42)
    x = _rand(rng, m, k)
    w = _grid_weights(rng, k, n)
    sx = jnp.asarray([0.02], jnp.float32)
    np.testing.assert_allclose(
        qmatmul(x, w, sx), qmatmul_ref(x, w, sx[0]), rtol=1e-5, atol=1e-4
    )
