"""L2 model definitions: shapes, determinism, recorder-metadata coherence
and masking semantics (the contract the Rust coordinator builds on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import models as zoo

MODELS = ["mobilenetv3", "resnet18"]


@pytest.fixture(scope="module", params=MODELS)
def bundle(request):
    name = request.param
    net = M.trace(name)
    params, order = zoo.get(name).init_params(seed=7)
    return name, net, params, order


class TestTraceMetadata:
    def test_param_order_matches_init(self, bundle):
        name, net, params, order = bundle
        assert order == net.param_order
        assert set(params.keys()) == set(order)

    def test_group_offsets_tile_filter_space(self, bundle):
        _, net, _, _ = bundle
        off = 0
        for g in net.groups:
            assert g.offset == off
            off += g.size

    def test_group_members_have_valid_axes(self, bundle):
        _, net, params, _ = bundle
        for g in net.groups:
            for pname, axis in g.members:
                assert params[pname].shape[axis] == g.size, (g.name, pname)

    def test_every_conv_has_a_tap(self, bundle):
        _, net, _, _ = bundle
        conv_like = [o for o in net.ops if o.kind in ("conv", "dwconv")]
        tapped = [o for o in conv_like if o.tap is not None]
        assert len(tapped) == len(conv_like)

    def test_ops_topologically_ordered(self, bundle):
        _, net, _, _ = bundle
        produced = {0}  # input tensor
        for o in net.ops:
            for t in o.inputs:
                assert t in produced, f"{o.name} uses unproduced tensor {t}"
            produced.add(o.output)


class TestForward:
    def test_output_shape_and_determinism(self, bundle):
        name, net, params, order = bundle
        ev = jax.jit(M.make_eval_logits(name, order))
        x = jnp.asarray(np.random.default_rng(0).normal(0.4, 0.2, (4, 32, 32, 3)), jnp.float32)
        a, = ev(M.params_to_list(params, order), x)
        b, = ev(M.params_to_list(params, order), x)
        assert a.shape == (4, 10)
        np.testing.assert_array_equal(a, b)

    def test_quant_mode_consumes_every_tap(self, bundle):
        name, net, params, order = bundle
        qe = jax.jit(M.make_quant_eval(name, order))
        x = jnp.zeros((2, 32, 32, 3), jnp.float32)
        scales = jnp.full((len(net.taps),), 0.05, jnp.float32)
        ql, = qe(M.params_to_list(params, order), scales, x)
        assert ql.shape == (2, 10)
        # (jnp clamps out-of-range indices, so a short scale vector cannot
        # be detected here; the Rust Session validates the length before
        # execution — see Session::quant_accuracy.)
        assert len(net.taps) > 0

    def test_absmax_scales_converge_to_fp32(self, bundle):
        name, net, params, order = bundle
        plist = M.params_to_list(params, order)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(0.4, 0.2, (4, 32, 32, 3)), jnp.float32)
        fl, = jax.jit(M.make_eval_logits(name, order))(plist, x)
        # full-range scales (absmax/127): fine grid, no saturation
        mx, _ = jax.jit(M.make_act_absmax(name, order))(plist, x)
        ql, = jax.jit(M.make_quant_eval(name, order))(plist, mx / 127.0, x)
        np.testing.assert_allclose(fl, ql, rtol=0.2, atol=0.15)


class TestMaskingSemantics:
    """Zeroing a group's members must be numerically identical to removing
    the filter — the keystone of the fixed-shape pruning design."""

    def test_masked_channel_contributes_nothing(self, bundle):
        name, net, params, order = bundle
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(0.4, 0.2, (2, 32, 32, 3)), jnp.float32)
        ev = jax.jit(M.make_eval_logits(name, order))

        # mask channel 0 of an early group via the member list
        masked = dict(params)
        g = net.groups[1]
        for pname, axis in g.members:
            arr = np.asarray(masked[pname]).copy()
            sl = [slice(None)] * arr.ndim
            sl[axis] = 0
            arr[tuple(sl)] = 0.0
            masked[pname] = jnp.asarray(arr)

        l_masked, = ev(M.params_to_list(masked, order), x)

        # masking again (idempotence) and scaling the masked slice by any
        # factor of zero must not change anything
        l_again, = ev(M.params_to_list(masked, order), x)
        np.testing.assert_array_equal(l_masked, l_again)

        # masked logits differ from baseline (the channel DID matter)...
        l_base, = ev(M.params_to_list(params, order), x)
        assert not np.allclose(l_base, l_masked), "channel 0 was already dead?"

    def test_bn_gamma_beta_must_be_in_members(self, bundle):
        # the masking-exactness argument requires every group that passes
        # through a BN to zero that BN's gamma AND beta
        _, net, _, _ = bundle
        for g in net.groups:
            names = [p for p, _ in g.members]
            gammas = [n for n in names if n.endswith(".gamma")]
            betas = [n for n in names if n.endswith(".beta")]
            assert len(gammas) == len(betas), g.name


def test_models_differ():
    a = M.trace("mobilenetv3")
    b = M.trace("resnet18")
    assert a.param_order != b.param_order
    assert any("dw" in o.name for o in a.ops)
    assert any(o.kind == "add" for o in b.ops)
