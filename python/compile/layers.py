"""L2 building blocks + the single-pass recorder.

A model here is a pure function over an ordered, flat list of named f32
arrays ("params-as-arguments"): the AOT-lowered HLO takes every parameter as
a runtime input, which is what lets the Rust coordinator mask filters
(structural pruning) and substitute INT8-grid weights (PTQ) without ever
re-lowering — the paper's entire Algorithm-1 loop runs in Rust against one
fixed artifact per model.

The `Net` object below is the recorder: the SAME model code path serves
  * init    — creates parameters (He init, deterministic PRNG),
  * apply   — plain forward (training with batch-norm batch stats, or eval
              with folded running stats),
  * trace   — records the op graph, prune groups, tap list and param layout
              that aot.py serializes into artifacts/manifest.json for the
              Rust graph IR (rust/src/graph),
  * quant   — fake-quant forward: each quantizable op consumes the next
              per-tensor activation scale (KL-calibrated in Rust) and the
              pointwise-conv / FC hot spots run through the L1 Pallas
              qmatmul kernel.
Because all four modes execute the same traversal, the tap order, scale
order, prune-group order and param order are consistent by construction.

Prune-group semantics (paper §III): a group is one conv's (or FC's) output
channel set — the unit Algorithm 1 removes. Masking a channel j of group g
zeroes, for every member (param, axis) of g, the j-th slice along axis.
Members include the producing weight tensor AND every per-channel parameter
downstream that could re-introduce a nonzero value into a zeroed channel
(BN gamma/beta, depthwise filters) up to the next channel-mixing op, so that
masked evaluation is numerically identical to true structural removal.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.qmatmul import qmatmul
from .kernels.ref import quantize_sym

BN_EPS = 1e-3
HIST_BINS = 2048  # TensorRT KL-calibration histogram resolution


# ---------------------------------------------------------------------------
# metadata records (serialized into manifest.json by aot.py)
# ---------------------------------------------------------------------------


@dataclass
class OpRec:
    """One node of the inference graph, mirrored by rust/src/graph."""

    id: int
    kind: str  # conv|dwconv|bn|act|add|gap|fc|se_mul|flatten
    name: str
    inputs: list  # tensor ids
    output: int  # tensor id
    attrs: dict = field(default_factory=dict)
    params: list = field(default_factory=list)  # param names used
    group: Optional[int] = None  # prune group that produces this op's output
    tap: Optional[int] = None  # index into the quantization tap list


@dataclass
class GroupRec:
    """One prune group = one ranked unit of Algorithm 1."""

    id: int
    name: str
    size: int  # number of filters/channels
    offset: int = 0  # filled by finalize(): index of filter 0 in the global S vector
    members: list = field(default_factory=list)  # [(param_name, axis), ...]
    producer_param: str = ""  # the conv/fc weight whose grads define S
    producer_axis: int = 0


@dataclass
class TapRec:
    """One quantizable activation (input of a conv/fc)."""

    id: int
    op_name: str
    shape: tuple


class Net:
    """Recorder + parameter store; see module docstring."""

    MODES = ("init", "apply", "trace", "quant")

    def __init__(
        self,
        mode: str,
        params: Optional[dict] = None,
        rng: Optional[np.random.Generator] = None,
        scales: Optional[jnp.ndarray] = None,
        train: bool = False,
        collect_taps: bool = False,
    ):
        assert mode in self.MODES, mode
        self.mode = mode
        self.params = params if params is not None else {}
        self.rng = rng
        self.scales = scales  # (n_taps,) f32, quant mode only
        self.train = train
        self.collect_taps = collect_taps

        self.param_order: list = []  # ordered names (layout contract with rust)
        self.ops: list = []
        self.groups: list = []
        self.taps: list = []
        self.tap_values: list = []  # activations captured when collect_taps
        self.bn_stats: dict = {}  # name -> (batch_mean, batch_var) in train mode
        self._tid = 0
        self._tensor_group: dict = {}  # tensor id -> group id
        self._tensor_shape: dict = {}

    # -- tensors ------------------------------------------------------------

    def input(self, x: jnp.ndarray) -> tuple:
        tid = self._new_tid(x.shape)
        return x, tid

    def _new_tid(self, shape) -> int:
        tid = self._tid
        self._tid += 1
        self._tensor_shape[tid] = tuple(int(d) for d in shape)
        return tid

    # -- params -------------------------------------------------------------

    def param(self, name: str, shape: tuple, init: str = "he", fan_in: int = 0):
        if name in self.param_order:
            raise ValueError(f"duplicate param {name}")
        self.param_order.append(name)
        if self.mode == "init":
            if init == "he":
                std = math.sqrt(2.0 / max(fan_in, 1))
                v = self.rng.normal(0.0, std, size=shape).astype(np.float32)
            elif init == "zeros":
                v = np.zeros(shape, np.float32)
            elif init == "ones":
                v = np.ones(shape, np.float32)
            else:
                raise ValueError(init)
            self.params[name] = jnp.asarray(v)
        elif self.mode == "trace":
            self.params.setdefault(name, jnp.zeros(shape, jnp.float32))
        arr = self.params[name]
        assert tuple(arr.shape) == tuple(shape), f"{name}: {arr.shape} != {shape}"
        return arr

    # -- op recording ---------------------------------------------------------

    def _record(self, kind, name, in_tids, out_shape, attrs=None, params=None,
                group=None, tap=None) -> int:
        out_tid = self._new_tid(out_shape)
        self.ops.append(
            OpRec(
                id=len(self.ops),
                kind=kind,
                name=name,
                inputs=list(in_tids),
                output=out_tid,
                attrs=attrs or {},
                params=params or [],
                group=group,
                tap=tap,
            )
        )
        return out_tid

    def _new_group(self, name: str, size: int, producer: str, axis: int) -> int:
        gid = len(self.groups)
        self.groups.append(
            GroupRec(
                id=gid,
                name=name,
                size=size,
                members=[(producer, axis)],
                producer_param=producer,
                producer_axis=axis,
            )
        )
        return gid

    def _tap(self, op_name: str, x: jnp.ndarray):
        """Register a quantizable activation; in quant mode consume the next
        scale and fake-quantize; in tap-collect mode stash the tensor."""
        tap_id = len(self.taps)
        self.taps.append(TapRec(id=tap_id, op_name=op_name, shape=tuple(x.shape)))
        if self.collect_taps:
            self.tap_values.append(x)
        if self.mode == "quant":
            s = self.scales[tap_id]
            x = quantize_sym(x, s)
        return x, tap_id

    # -- layers ---------------------------------------------------------------

    def conv(self, name, xt, cout, k, stride=1, groups=1, quantizable=True):
        """Conv2D, NHWC/HWIO, SAME padding, no bias (BN follows).

        groups == cin means depthwise: the output channels belong to the
        *input's* prune group (per-channel op); otherwise a fresh prune
        group is created for the cout output channels.
        """
        x, tid = xt
        cin = int(x.shape[-1])
        depthwise = groups == cin and groups > 1
        w = self.param(name + ".w", (k, k, cin // groups, cout), fan_in=k * k * cin // groups)
        pointwise = k == 1 and groups == 1 and stride == 1

        tap = None
        pallas_path = False
        if quantizable:
            if self.mode == "quant" and pointwise:
                # INT8 path for pointwise convs: a GEMM over the pixel axis —
                # the L1 Pallas kernel territory (the MobileNetV3 hot spot).
                # The kernel performs the activation quantization itself, so
                # register the tap without pre-quantizing.
                tap = len(self.taps)
                self.taps.append(TapRec(id=tap, op_name=name, shape=tuple(x.shape)))
                pallas_path = True
            else:
                x, tap = self._tap(name, x)

        if depthwise:
            gid = self._tensor_group.get(tid)
            if gid is not None:
                self.groups[gid].members.append((name + ".w", 3))
        else:
            gid = self._new_group(name, cout, name + ".w", 3)

        if self.mode == "trace":
            h, wd = int(x.shape[1]), int(x.shape[2])
            ho, wo = -(-h // stride), -(-wd // stride)
            y = jnp.zeros((x.shape[0], ho, wo, cout), jnp.float32)
        elif pallas_path:
            n, h, wd, _ = x.shape
            sx = self.scales[tap]
            ym = qmatmul(x.reshape(n * h * wd, cin), w.reshape(cin, cout), sx.reshape(1))
            y = ym.reshape(n, h, wd, cout)
        else:
            y = jax.lax.conv_general_dilated(
                x,
                w,
                window_strides=(stride, stride),
                padding="SAME",
                feature_group_count=groups,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
        out_tid = self._record(
            "dwconv" if depthwise else "conv",
            name,
            [tid],
            y.shape,
            attrs=dict(cin=cin, cout=cout, k=k, stride=stride, groups=groups,
                       h=int(y.shape[1]), w=int(y.shape[2])),
            params=[name + ".w"],
            group=gid,
            tap=tap,
        )
        if gid is not None:
            self._tensor_group[out_tid] = gid
        return y, out_tid

    def bn(self, name, xt):
        """BatchNorm. Params: gamma/beta (trainable) + mean/var (running,
        updated by train.py via EMA, folded as plain arguments at export).
        gamma/beta join the input tensor's prune group (zeroing them is what
        makes channel masking exact — see module docstring)."""
        x, tid = xt
        c = int(x.shape[-1])
        g = self.param(name + ".gamma", (c,), init="ones")
        b = self.param(name + ".beta", (c,), init="zeros")
        mu = self.param(name + ".mean", (c,), init="zeros")
        var = self.param(name + ".var", (c,), init="ones")

        gid = self._tensor_group.get(tid)
        if gid is not None:
            self.groups[gid].members.append((name + ".gamma", 0))
            self.groups[gid].members.append((name + ".beta", 0))

        if self.mode == "trace":
            y = x
        elif self.train:
            bm = jnp.mean(x, axis=(0, 1, 2))
            bv = jnp.var(x, axis=(0, 1, 2))
            self.bn_stats[name] = (bm, bv)
            y = g * (x - bm) / jnp.sqrt(bv + BN_EPS) + b
        else:
            y = g * (x - mu) / jnp.sqrt(var + BN_EPS) + b
        out_tid = self._record(
            "bn", name, [tid], y.shape, attrs=dict(c=c),
            params=[name + ".gamma", name + ".beta", name + ".mean", name + ".var"],
            group=gid,
        )
        if gid is not None:
            self._tensor_group[out_tid] = gid
        return y, out_tid

    def act(self, name, xt, kind):
        x, tid = xt
        if self.mode == "trace":
            y = x
        elif kind == "relu":
            y = jax.nn.relu(x)
        elif kind == "hswish":
            y = x * jax.nn.relu6(x + 3.0) / 6.0
        elif kind == "hsigmoid":
            y = jax.nn.relu6(x + 3.0) / 6.0
        else:
            raise ValueError(kind)
        gid = self._tensor_group.get(tid)
        out_tid = self._record("act", name, [tid], y.shape, attrs=dict(kind=kind), group=gid)
        if gid is not None:
            self._tensor_group[out_tid] = gid
        return y, out_tid

    def add(self, name, at, bt):
        a, ta = at
        b, tb = bt
        y = a if self.mode == "trace" else a + b
        out_tid = self._record("add", name, [ta, tb], a.shape)
        return y, out_tid

    def se(self, name, xt, reduce_ratio=4):
        """Squeeze-and-Excitation. The reduce FC creates its own prune group;
        the expand FC writes into the trunk group's channels (zero input ->
        sigmoid(bias) gate, but the gated tensor is already zero there, so
        no extra members needed for masking exactness)."""
        x, tid = xt
        c = int(x.shape[-1])
        cr = max(c // reduce_ratio, 4)
        if self.mode == "trace":
            pooled = jnp.zeros((x.shape[0], c), jnp.float32)
        else:
            pooled = jnp.mean(x, axis=(1, 2))
        p_tid = self._record("gap", name + ".squeeze", [tid], pooled.shape)

        w1 = self.param(name + ".fc1.w", (c, cr), fan_in=c)
        b1 = self.param(name + ".fc1.b", (cr,), init="zeros")
        gid1 = self._new_group(name + ".fc1", cr, name + ".fc1.w", 1)
        self.groups[gid1].members.append((name + ".fc1.b", 0))
        if self.mode == "trace":
            h1 = jnp.zeros((x.shape[0], cr), jnp.float32)
        else:
            h1 = jax.nn.relu(pooled @ w1 + b1)
        h1_tid = self._record(
            "fc", name + ".fc1", [p_tid], h1.shape,
            attrs=dict(cin=c, cout=cr), params=[name + ".fc1.w", name + ".fc1.b"],
            group=gid1,
        )
        self._tensor_group[h1_tid] = gid1

        w2 = self.param(name + ".fc2.w", (cr, c), fan_in=cr)
        b2 = self.param(name + ".fc2.b", (c,), init="zeros")
        if self.mode == "trace":
            gate = jnp.zeros((x.shape[0], c), jnp.float32)
        else:
            gate = jax.nn.relu6(h1 @ w2 + b2 + 3.0) / 6.0
        g_tid = self._record(
            "fc", name + ".fc2", [h1_tid], gate.shape,
            attrs=dict(cin=cr, cout=c), params=[name + ".fc2.w", name + ".fc2.b"],
        )
        y = x if self.mode == "trace" else x * gate[:, None, None, :]
        out_tid = self._record("se_mul", name + ".mul", [tid, g_tid], x.shape)
        trunk_gid = self._tensor_group.get(tid)
        if trunk_gid is not None:
            self._tensor_group[out_tid] = trunk_gid
        return y, out_tid

    def gap(self, name, xt):
        x, tid = xt
        if self.mode == "trace":
            y = jnp.zeros((x.shape[0], x.shape[-1]), jnp.float32)
        else:
            y = jnp.mean(x, axis=(1, 2))
        out_tid = self._record("gap", name, [tid], y.shape)
        gid = self._tensor_group.get(tid)
        if gid is not None:
            self._tensor_group[out_tid] = gid
        return y, out_tid

    def fc(self, name, xt, cout, prunable=True, quantizable=True):
        """Dense layer (with bias). In quant mode the GEMM runs through the
        Pallas qmatmul kernel."""
        x, tid = xt
        cin = int(x.shape[-1])
        w = self.param(name + ".w", (cin, cout), fan_in=cin)
        b = self.param(name + ".b", (cout,), init="zeros")
        tap = None
        if quantizable:
            if self.mode == "quant":
                tap = len(self.taps)
                self.taps.append(TapRec(id=tap, op_name=name, shape=tuple(x.shape)))
                sx = self.scales[tap]
                y = qmatmul(x, w, sx.reshape(1)) + b
            else:
                x, tap = self._tap(name, x)
                y = x @ w + b if self.mode != "trace" else jnp.zeros((x.shape[0], cout), jnp.float32)
        else:
            y = x @ w + b if self.mode != "trace" else jnp.zeros((x.shape[0], cout), jnp.float32)

        gid = None
        if prunable:
            gid = self._new_group(name, cout, name + ".w", 1)
            self.groups[gid].members.append((name + ".b", 0))
        out_tid = self._record(
            "fc", name, [tid], y.shape, attrs=dict(cin=cin, cout=cout),
            params=[name + ".w", name + ".b"], group=gid, tap=tap,
        )
        if gid is not None:
            self._tensor_group[out_tid] = gid
        return y, out_tid

    # -- finalize -------------------------------------------------------------

    def finalize(self):
        """Assign global filter offsets (the index space of the S vector and
        of Algorithm 1's ranked list R)."""
        off = 0
        for g in self.groups:
            g.offset = off
            off += g.size
        return off
