"""L2 exported function set — the contract between JAX (build time) and the
Rust coordinator (run time).

Every function takes the model parameters as a flat ORDERED list of arrays
(order = manifest `param_order`), so the lowered HLO exposes each parameter
as a runtime argument. That is the mechanism that lets the Rust side run the
whole HQP loop — filter masking (structural pruning), INT8-grid weight
substitution (PTQ) and per-tensor activation scales — against a handful of
fixed artifacts, with Python never on the request path.

Exported per model (aot.py lowers each to artifacts/<model>_<fn>.hlo.txt):

  eval_logits(params, x)            -> (B, C) logits           [HQP val loop]
  fisher_gradsq(params, x, y)       -> (F,) S-vector contribution of a
                                       microbatch: per-sample grads via
                                       vmap(grad), reduced per filter by the
                                       L1 Pallas fisher kernel [HQP Phase 1-A]
  act_absmax(params, x)             -> (T,) per-tap max|activation|
  act_hist(params, x, ranges)       -> (T, 2048) |activation| histograms
                                       (TensorRT KL-calibration recipe)
  quant_eval(params_q, scales, x)   -> (B, C) logits through the fake-quant
                                       INT8 graph (Pallas qmatmul hot spots)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import models as model_zoo
from .kernels.fisher import fisher_accumulate
from .layers import HIST_BINS, Net

EVAL_BATCH = 256
FISHER_BATCH = 16
HIST_BATCH = 256


# ---------------------------------------------------------------------------
# trace: one dry traversal -> metadata (groups, taps, ops, param layout)
# ---------------------------------------------------------------------------


def trace(model_name: str):
    mod = model_zoo.get(model_name)
    net = Net("trace")
    x = jnp.zeros((1, mod.INPUT_HW, mod.INPUT_HW, 3), jnp.float32)
    mod.forward(net, x)
    return net


def params_to_list(params: dict, order: list) -> list:
    return [params[n] for n in order]


def list_to_params(plist: list, order: list) -> dict:
    return dict(zip(order, plist))


# ---------------------------------------------------------------------------
# exported functions
# ---------------------------------------------------------------------------


def make_eval_logits(model_name: str, order: list):
    mod = model_zoo.get(model_name)

    def eval_logits(plist, x):
        net = Net("apply", params=list_to_params(plist, order))
        return (mod.forward(net, x),)

    return eval_logits


def make_quant_eval(model_name: str, order: list):
    mod = model_zoo.get(model_name)

    def quant_eval(plist, scales, x):
        net = Net("quant", params=list_to_params(plist, order), scales=scales)
        return (mod.forward(net, x),)

    return quant_eval


def make_act_absmax(model_name: str, order: list):
    mod = model_zoo.get(model_name)

    def act_absmax(plist, x):
        net = Net("apply", params=list_to_params(plist, order), collect_taps=True)
        logits = mod.forward(net, x)
        # logits are returned too so every parameter is a live input of the
        # lowered module — XLA DCE would otherwise prune the classifier
        # weights (taps don't depend on them) and shift the HLO arg count.
        return (jnp.stack([jnp.max(jnp.abs(t)) for t in net.tap_values]), logits)

    return act_absmax


def make_act_hist(model_name: str, order: list):
    mod = model_zoo.get(model_name)

    def act_hist(plist, x, ranges):
        """Per-tap histogram of |activation| over [0, ranges[i]], 2048 bins.
        Values above the range clamp into the top bin (the calibration pass
        uses the global absmax as the range, so clamping only guards
        numerics)."""
        net = Net("apply", params=list_to_params(plist, order), collect_taps=True)
        logits = mod.forward(net, x)
        outs = []
        for i, t in enumerate(net.tap_values):
            a = jnp.abs(t).reshape(-1)
            r = jnp.maximum(ranges[i], 1e-12)
            idx = jnp.clip((a / r * HIST_BINS).astype(jnp.int32), 0, HIST_BINS - 1)
            outs.append(jnp.bincount(idx, length=HIST_BINS).astype(jnp.float32))
        # logits keep all params live in the lowered HLO (see act_absmax).
        return (jnp.stack(outs), logits)

    return act_hist


def make_fisher_gradsq(model_name: str, order: list, groups):
    """S-vector contribution of one microbatch (paper §II-B):

        S_f += sum_i || dL(W, x_i, y_i) / dW_f ||^2

    Per-SAMPLE gradients (the FIM definition — not the squared batch
    gradient) via vmap(grad(per_sample_loss)) w.r.t. only the producer
    weight tensors, then the Pallas fisher kernel reduces each producer's
    (B, F, E) grad slab to per-filter scores, concatenated in group order
    (offsets = manifest `groups[i].offset`).
    """
    mod = model_zoo.get(model_name)
    producer_set = {g.producer_param for g in groups}

    def per_sample_loss(prod_params: dict, rest_params: dict, x, y):
        params = dict(rest_params)
        params.update(prod_params)
        net = Net("apply", params=params)
        logits = mod.forward(net, x[None])[0]
        logp = jax.nn.log_softmax(logits)
        return -logp[y]

    grad_fn = jax.grad(per_sample_loss, argnums=0)

    def fisher_gradsq(plist, x, y):
        params = list_to_params(plist, order)
        prod = {n: params[n] for n in producer_set}
        rest = {n: v for n, v in params.items() if n not in producer_set}
        g = jax.vmap(grad_fn, in_axes=(None, None, 0, 0))(prod, rest, x, y)
        pieces = []
        for grp in groups:
            gw = g[grp.producer_param]  # (B, *w.shape)
            ax = grp.producer_axis + 1  # account for batch axis
            gw = jnp.moveaxis(gw, ax, 1)  # (B, F, ...)
            b, f = gw.shape[0], gw.shape[1]
            gw = gw.reshape(b, f, -1)
            pieces.append(fisher_accumulate(gw))  # L1 Pallas kernel
        return (jnp.concatenate(pieces),)

    return fisher_gradsq


# ---------------------------------------------------------------------------
# training-side helpers (used by train.py, not exported)
# ---------------------------------------------------------------------------


def make_train_loss(model_name: str, order: list):
    mod = model_zoo.get(model_name)

    def loss_fn(trainable: dict, stats: dict, x, y):
        params = dict(stats)
        params.update(trainable)
        net = Net("apply", params=params, train=True)
        logits = mod.forward(net, x)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
        # L2 regularization on conv/fc weights only
        wd = sum(jnp.sum(v * v) for n, v in trainable.items() if n.endswith(".w"))
        return loss + 1e-4 * wd, net.bn_stats

    return loss_fn


def accuracy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
