"""L1 Pallas kernel: per-filter Fisher (diagonal FIM) accumulation.

The HQP sensitivity metric (paper §II-B) is

    S_f = (1/|Dcalib|) * sum_i || dL(W, x_i, y_i)/dW_f ||^2

i.e. for every prunable filter f, the sum over calibration samples of the
squared L2 norm of that sample's gradient w.r.t. the filter's weights. L2
(model.py) produces per-sample gradients g of shape (B, F, E) — B samples,
F filters, E = kernel elements per filter; this kernel reduces them to the
(F,) per-filter scores. It is the hot reduction of HQP Phase 1-A: for a
model with P parameters and a B-sample microbatch the input is B*P floats.

TPU mapping: each grid step loads a (B, bf, E) slab into VMEM, squares on
the VPU, and accumulates an (bf,) partial in the output tile. Grid sweeps
the filter axis so arbitrarily many filters stream through a fixed VMEM
budget. interpret=True for CPU-PJRT execution (see qmatmul.py docstring).

Correctness oracle: ref.fisher_ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BF = 128  # filters per grid step


def _fisher_kernel(g_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(g * g, axis=(0, 2))


def fisher_accumulate(g: jnp.ndarray, *, bf: int = DEFAULT_BF) -> jnp.ndarray:
    """Reduce per-sample gradients (B, F, E) -> per-filter scores (F,):
    S_f = sum_{b,e} g[b,f,e]^2. Edge blocks are zero-padded, which is exact
    for a sum of squares."""
    b, f, e = g.shape
    bf = min(bf, f)
    grid = (pl.cdiv(f, bf),)
    return pl.pallas_call(
        _fisher_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((b, bf, e), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((bf,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((f,), jnp.float32),
        interpret=True,
    )(g)
