"""L1 Pallas kernel: fake-quant INT8 tiled GEMM (the paper's compute hot-spot).

HQP's deployed model executes INT8 GEMMs (pointwise 1x1 convolutions are
reshaped to GEMMs; the classifier head is a GEMM). The paper runs these on
Jetson Tensor Cores via TensorRT; the TPU-style rethink (DESIGN.md
§Hardware-Adaptation) is:

  * BlockSpec tiles sized for VMEM (the TPU scratchpad), not CUDA shared
    memory: an (bm x bk) activation tile, a (bk x bn) weight tile and an
    (bm x bn) f32 accumulator live in VMEM across the K-sweep.
  * The inner product targets the MXU systolic array via a dense
    `jnp.dot(..., preferred_element_type=f32)` on the tile; the
    quantize/clip/round element-wise ops vectorize on the VPU.
  * The HBM<->VMEM schedule the paper expresses with threadblocks is the
    BlockSpec grid: (M/bm, N/bn, K/bk), with K innermost so the accumulator
    tile stays resident (double-buffered tile streaming is the Mosaic
    default on real hardware).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO ops so the same
artifact runs under the rust runtime. Real-TPU efficiency is estimated from
the VMEM footprint / MXU-utilization report in aot.py --report.

Correctness oracle: ref.qmatmul_ref (pytest + hypothesis sweeps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import QMAX, QMIN

# Default block shapes: MXU-shaped (128x128) output tiles with a 128-deep
# K-slab. f32 VMEM footprint = (bm*bk + bk*bn + bm*bn) * 4B = 192 KiB —
# comfortably inside a ~16 MiB VMEM budget, leaving room for double
# buffering. See aot.py --report for the footprint/utilization table.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _qmatmul_kernel(x_ref, w_ref, sx_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: o[i,j] += quant(x[i,k]) @ w[k,j].

    The K grid axis is innermost, so o_ref (the VMEM accumulator tile) is
    revisited nk times; we zero it on the first visit.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    sx = sx_ref[0]
    # VPU: fake-quantize the activation tile onto the symmetric INT8 grid.
    xq = jnp.clip(jnp.round(x_ref[...] / sx), QMIN, QMAX) * sx
    # MXU: dense f32 tile product (bit-identical to int8*int8->int32 deq,
    # since both operands are exact small-integer multiples of scales).
    o_ref[...] += jnp.dot(xq, w_ref[...], preferred_element_type=jnp.float32)


def qmatmul(
    x: jnp.ndarray,
    wq: jnp.ndarray,
    sx: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jnp.ndarray:
    """Fake-quant INT8 GEMM: quantize `x` per-tensor with scale `sx`, then
    (M,K) @ (K,N) with f32 accumulation. `wq` must already lie on its int8
    grid (offline per-channel quantization, scales folded in).

    Shapes need not be multiples of the block sizes: inputs are explicitly
    zero-padded up to block multiples here (interpret-mode Pallas fills
    out-of-bounds block reads with NaN, so relying on implicit padding would
    poison the accumulation; zero padding is exact for GEMM+sum), and the
    output is sliced back. `sx` is a shape-(1,) f32 array.
    """
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {wq.shape}"
    assert sx.shape == (1,), f"sx must be shape (1,), got {sx.shape}"
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        wq = jnp.pad(wq, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_qmatmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(x, wq, sx)
    return out[:m, :n] if (mp, np_) != (m, n) else out


def vmem_footprint_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint of one grid step (x-tile + w-tile + acc-tile).
    Used by the aot.py --report roofline estimator; doubled there for the
    double-buffered streaming the Mosaic pipeline applies on real TPUs."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issued MACs that are useful (not edge padding)."""
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk)
    issued = gm * bm * gn * bn * gk * bk
    return (m * n * k) / issued
