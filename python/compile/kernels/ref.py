"""Pure-jnp correctness oracles for the Pallas kernels.

These are the reference semantics the kernels in qmatmul.py / fisher.py must
match bit-for-bit (same rounding mode, same accumulation dtype). pytest +
hypothesis sweep shapes/dtypes against these (python/tests/test_kernels.py).
"""

from __future__ import annotations

import jax.numpy as jnp

# INT8 symmetric grid bounds (TensorRT-style symmetric signed quantization
# uses [-127, 127] so the grid is symmetric around zero; -128 is unused).
QMIN = -127.0
QMAX = 127.0


def quantize_sym(x: jnp.ndarray, scale) -> jnp.ndarray:
    """Fake-quantize to the symmetric INT8 grid: round-to-nearest-even,
    clip to [-127,127], values returned on the dequantized (f32) grid."""
    q = jnp.clip(jnp.round(x / scale), QMIN, QMAX)
    return q * scale


def qmatmul_ref(x: jnp.ndarray, wq: jnp.ndarray, sx: jnp.ndarray) -> jnp.ndarray:
    """Reference fake-quant INT8 GEMM.

    x  : (M, K) f32 activations (unquantized).
    wq : (K, N) f32 weights ALREADY on the int8 grid (pre-quantized offline,
         per-output-channel scales folded in — i.e. wq = round(w/sw)*sw).
    sx : scalar f32 activation scale (per-tensor, from KL calibration).

    Semantics: quantize activations to the int8 grid, then dense GEMM with
    f32 accumulation. Because both operands hold exact small-integer
    multiples of their scales, the f32 GEMM is bit-identical to an int8
    GEMM with int32 accumulation followed by dequantization.
    """
    xq = quantize_sym(x, sx)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def fisher_ref(g: jnp.ndarray) -> jnp.ndarray:
    """Reference per-filter Fisher accumulation.

    g : (B, F, E) f32 — per-sample gradients, reshaped so axis 0 is the
        sample axis, axis 1 the filter axis, axis 2 everything else
        (kernel spatial x input-channel elements).

    Returns (F,) f32: S_f = sum_b ||g[b, f, :]||^2  — the diagonal-FIM
    per-filter sensitivity contribution of this batch (paper §II-B).
    """
    return jnp.sum(g.astype(jnp.float32) ** 2, axis=(0, 2))
