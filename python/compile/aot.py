"""AOT driver: python runs ONCE, here — never on the request path.

`python -m compile.aot --out ../artifacts` (via `make artifacts`):
  1. generates + saves the synthetic data splits (.npy),
  2. trains both benchmark models (skipped if weights already saved),
  3. lowers the five exported functions per model to HLO *text*,
  4. writes artifacts/manifest.json — the complete L2->L3 contract
     (param layout, prune groups, taps, op graph, artifact arg specs).

HLO TEXT, not `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published `xla`
0.1.6 crate binds) rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

`--report` prints the L1 kernel VMEM-footprint / MXU-utilization table used
for the §Perf TPU-efficiency estimate (interpret=True wall-clock is NOT a
TPU proxy — we optimize kernel structure, not CPU timings).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen
from . import model as M
from . import models as model_zoo
from . import train as T
from .layers import HIST_BINS

MODELS = ["mobilenetv3", "resnet18"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), {"f32": jnp.float32, "i32": jnp.int32}[dtype])


def save_npy(path: str, arr: np.ndarray):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.save(path, arr)


# ---------------------------------------------------------------------------


def export_data(out: str, manifest: dict):
    manifest["data"] = {}
    for split in ["calib", "val", "test"]:
        xs, ys = datagen.generate_split(split)
        save_npy(f"{out}/data/{split}_x.npy", xs)
        save_npy(f"{out}/data/{split}_y.npy", ys.astype(np.int32))
        manifest["data"][split] = dict(
            x=f"data/{split}_x.npy", y=f"data/{split}_y.npy", n=int(xs.shape[0])
        )


def export_model(name: str, out: str, manifest: dict, fast: bool, log=print):
    mod = model_zoo.get(name)
    net = M.trace(name)
    order = net.param_order

    # -- weights (train once, reuse thereafter) -----------------------------
    wdir = f"{out}/weights/{name}"
    if os.path.isdir(wdir) and len(os.listdir(wdir)) == len(order):
        log(f"[{name}] weights already trained, reusing {wdir}")
        params = {
            n: jnp.asarray(np.load(f"{wdir}/p{i:04d}.npy"))
            for i, n in enumerate(order)
        }
        baseline = T.evaluate(name, order, params, split="val")
    else:
        epochs = 1 if fast else (9 if name == "resnet18" else 8)
        # MobileNetV3's tiny depthwise/SE blocks train best at a gentler LR.
        lr = 0.05 if name == "mobilenetv3" else 0.08
        params, order2, _hist = T.train_model(name, epochs=epochs, lr=lr, log=log)
        assert order2 == order
        for i, n in enumerate(order):
            save_npy(f"{wdir}/p{i:04d}.npy", np.asarray(params[n]))
        baseline = T.evaluate(name, order, params, split="val")
        log(f"[{name}] baseline val accuracy: {baseline:.4f}")

    plist = M.params_to_list(params, order)
    n_taps = len(net.taps)

    # -- lower the exported function set ------------------------------------
    pspecs = [_spec(p.shape) for p in plist]
    hw = mod.INPUT_HW
    arts = {}

    def lower(fn_name, fn, extra_specs, extra_args, outputs):
        t0 = time.time()
        lowered = jax.jit(fn).lower(pspecs, *extra_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}_{fn_name}.hlo.txt"
        with open(f"{out}/{fname}", "w") as f:
            f.write(text)
        arts[fn_name] = dict(file=fname, extra_args=extra_args, outputs=outputs)
        log(f"[{name}] lowered {fn_name}: {len(text)} chars ({time.time()-t0:.1f}s)")

    eb, fb, hb = M.EVAL_BATCH, M.FISHER_BATCH, M.HIST_BATCH
    lower(
        "eval", M.make_eval_logits(name, order),
        [_spec((eb, hw, hw, 3))],
        [["x", [eb, hw, hw, 3], "f32"]],
        [["logits", [eb, mod.NUM_CLASSES], "f32"]],
    )
    lower(
        "fisher", M.make_fisher_gradsq(name, order, net.groups),
        [_spec((fb, hw, hw, 3)), _spec((fb,), "i32")],
        [["x", [fb, hw, hw, 3], "f32"], ["y", [fb], "i32"]],
        [["s", [sum(g.size for g in net.groups)], "f32"]],
    )
    lower(
        "absmax", M.make_act_absmax(name, order),
        [_spec((hb, hw, hw, 3))],
        [["x", [hb, hw, hw, 3], "f32"]],
        [["absmax", [n_taps], "f32"], ["logits", [hb, mod.NUM_CLASSES], "f32"]],
    )
    lower(
        "hist", M.make_act_hist(name, order),
        [_spec((hb, hw, hw, 3)), _spec((n_taps,))],
        [["x", [hb, hw, hw, 3], "f32"], ["ranges", [n_taps], "f32"]],
        [["hist", [n_taps, HIST_BINS], "f32"], ["logits", [hb, mod.NUM_CLASSES], "f32"]],
    )
    lower(
        "quant_eval", M.make_quant_eval(name, order),
        [_spec((n_taps,)), _spec((eb, hw, hw, 3))],
        [["scales", [n_taps], "f32"], ["x", [eb, hw, hw, 3], "f32"]],
        [["logits", [eb, mod.NUM_CLASSES], "f32"]],
    )

    # -- manifest entry ------------------------------------------------------
    manifest["models"][name] = dict(
        input_hw=hw,
        num_classes=mod.NUM_CLASSES,
        baseline_val_acc=float(baseline),
        eval_batch=eb,
        fisher_batch=fb,
        hist_batch=hb,
        weights_dir=f"weights/{name}",
        param_order=[
            dict(name=n, shape=list(np.asarray(params[n]).shape)) for n in order
        ],
        groups=[
            dict(
                id=g.id, name=g.name, size=g.size, offset=g.offset,
                members=[[p, a] for (p, a) in g.members],
                producer=g.producer_param, producer_axis=g.producer_axis,
            )
            for g in net.groups
        ],
        taps=[dict(id=t.id, op=t.op_name, shape=list(t.shape)) for t in net.taps],
        ops=[
            dict(
                id=o.id, kind=o.kind, name=o.name, inputs=o.inputs,
                output=o.output, attrs=o.attrs, params=o.params,
                group=o.group, tap=o.tap,
            )
            for o in net.ops
        ],
        tensor_shapes={str(k): list(v) for k, v in net._tensor_shape.items()},
        artifacts=arts,
    )


def kernel_report():
    """§Perf L1: VMEM footprint + MXU utilization across block-shape
    candidates for the qmatmul kernel at the deployed GEMM shapes."""
    from .kernels.qmatmul import mxu_utilization, vmem_footprint_bytes

    shapes = []
    for name in MODELS:
        net = M.trace(name)
        for op in net.ops:
            if op.kind == "conv" and op.attrs.get("k") == 1 and op.attrs.get("groups", 1) == 1:
                a = op.attrs
                shapes.append((name, op.name, M.EVAL_BATCH * a["h"] * a["w"], a["cin"], a["cout"]))
            elif op.kind == "fc" and "cin" in op.attrs:
                shapes.append((name, op.name, M.EVAL_BATCH, op.attrs["cin"], op.attrs["cout"]))

    print(f"{'gemm':44s} {'M':>8s} {'K':>5s} {'N':>5s} | block   VMEM(KiB,x2buf)  MXU-util")
    for bm, bn, bk in [(128, 128, 128), (256, 128, 128), (128, 128, 256), (512, 256, 128)]:
        print(f"--- block ({bm},{bn},{bk}) ---")
        for (mname, oname, m, k, n) in shapes:
            vm = 2 * vmem_footprint_bytes(min(bm, m), min(bn, n), min(bk, k)) / 1024
            ut = mxu_utilization(m, n, k, min(bm, m), min(bn, n), min(bk, k))
            print(f"{mname+'/'+oname:44s} {m:8d} {k:5d} {n:5d} |        {vm:10.0f}      {ut:8.2%}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--fast", action="store_true", help="1-epoch smoke training")
    ap.add_argument("--report", action="store_true", help="print L1 kernel roofline report")
    ap.add_argument("--models", default=",".join(MODELS))
    args = ap.parse_args()

    if args.report:
        kernel_report()
        return

    out = args.out
    os.makedirs(out, exist_ok=True)
    manifest = dict(version=1, hist_bins=HIST_BINS, models={})
    export_data(out, manifest)
    for name in args.models.split(","):
        export_model(name, out, manifest, fast=args.fast)
    with open(f"{out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
