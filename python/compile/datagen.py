"""Synthetic procedural image dataset ("SynthEdge-10").

Substitute for ImageNet-1000 (unavailable in this environment — see
DESIGN.md §Substitutions). 10 classes of 32x32 RGB images. The class signal
is deliberately *fine-grained* so that a small CNN reaches high-but-NOT-
saturated accuracy and — critically for reproducing HQP's evaluation —
compression perturbations (filter masking, INT8 rounding) produce graded,
measurable accuracy drops rather than no-ops:

  * class = (shape kind in {disc, square, triangle, ring, cross}) x
            (stripe texture frequency in {low, high})
  * the 5 shape families also carry a (jittered) palette, so the coarse
    5-way split is learned quickly; the paired classes (k vs k+5) differ
    ONLY in stripe frequency — a fine-grained, perturbation-sensitive
    signal that INT8 rounding and filter masking measurably erode,
  * scale / rotation / position jitter, a random occluding rectangle,
  * additive Gaussian noise and photometric gain/bias jitter.

Everything derives from a counter-based deterministic PRNG (numpy Philox),
so the train/calib/val/test splits are bit-reproducible across runs and
across the python/rust boundary.
"""

from __future__ import annotations

import numpy as np

IMG = 32
NUM_CLASSES = 10


def _rng(seed: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=seed))


# Per-shape-family palettes (fg, bg) — jittered per sample in make_image.
_FG = [
    (0.85, 0.30, 0.30),
    (0.30, 0.80, 0.35),
    (0.30, 0.40, 0.85),
    (0.85, 0.80, 0.30),
    (0.75, 0.35, 0.80),
]
_BG = [
    (0.15, 0.15, 0.30),
    (0.30, 0.15, 0.15),
    (0.15, 0.28, 0.15),
    (0.28, 0.15, 0.28),
    (0.15, 0.28, 0.28),
]


def _shape_mask(kind: int, cx: float, cy: float, r: float, ang: float) -> np.ndarray:
    """Shape-family mask on the 32x32 grid (5 families)."""
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32)
    x = (xx - cx) / r
    y = (yy - cy) / r
    ca, sa = np.cos(ang), np.sin(ang)
    xr = ca * x - sa * y
    yr = sa * x + ca * y
    if kind == 0:  # disc
        return (xr * xr + yr * yr) < 1.0
    if kind == 1:  # square
        return (np.abs(xr) < 0.85) & (np.abs(yr) < 0.85)
    if kind == 2:  # triangle
        return (yr > -0.75) & (yr < 1.6 * xr + 0.8) & (yr < -1.6 * xr + 0.8)
    if kind == 3:  # ring
        rr = xr * xr + yr * yr
        return (rr < 1.0) & (rr > 0.45)
    # cross
    return (np.abs(xr) < 0.32) | (np.abs(yr) < 0.32)


def make_image(cls: int, rng: np.random.Generator) -> np.ndarray:
    """One 32x32x3 float32 image of class `cls` (see module docstring)."""
    shape_kind = cls % 5
    fine_texture = cls >= 5

    # Palette keyed to the shape family (coarse signal), heavily jittered.
    base_fg = np.array(_FG[shape_kind], np.float32)
    base_bg = np.array(_BG[shape_kind], np.float32)
    fg = np.clip(base_fg + rng.uniform(-0.18, 0.18, size=3).astype(np.float32), 0.05, 1.0)
    bg = np.clip(base_bg + rng.uniform(-0.18, 0.18, size=3).astype(np.float32), 0.0, 0.9)

    cx = 16.0 + rng.uniform(-4, 4)
    cy = 16.0 + rng.uniform(-4, 4)
    r = rng.uniform(6.0, 10.5)
    ang = rng.uniform(0, 2 * np.pi)
    mask = _shape_mask(shape_kind, cx, cy, r, ang).astype(np.float32)

    # Texture: stripe frequency is the second half of the class signal.
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32)
    freq = 1.3 if fine_texture else 0.5
    phase = rng.uniform(0, 2 * np.pi)
    orient = rng.uniform(0, np.pi)
    axis = np.cos(orient) * xx + np.sin(orient) * yy
    stripes = 0.5 + 0.5 * np.sin(freq * axis + phase)

    img = np.empty((IMG, IMG, 3), dtype=np.float32)
    for c in range(3):
        base = bg[c] * (0.75 + 0.25 * stripes)
        img[..., c] = base * (1.0 - mask) + fg[c] * mask * (0.55 + 0.45 * stripes)

    # Random occluding rectangle (drops part of the evidence).
    if rng.uniform() < 0.35:
        ow = int(rng.integers(3, 8))
        oh = int(rng.integers(3, 8))
        ox = int(rng.integers(0, IMG - ow))
        oy = int(rng.integers(0, IMG - oh))
        img[oy : oy + oh, ox : ox + ow, :] = rng.uniform(0.0, 1.0, size=3).astype(
            np.float32
        )

    # Photometric jitter + noise.
    gain = rng.uniform(0.75, 1.25)
    bias = rng.uniform(-0.08, 0.08)
    noise = rng.normal(0.0, 0.10, size=img.shape).astype(np.float32)
    img = np.clip(img * gain + bias + noise, 0.0, 1.0)
    return img


def make_split(n: int, seed: int, label_noise: float = 0.0):
    """Generate `n` (image, label) pairs."""
    rng = _rng(seed)
    xs = np.empty((n, IMG, IMG, 3), dtype=np.float32)
    ys = np.empty((n,), dtype=np.int32)
    for i in range(n):
        cls = int(rng.integers(0, NUM_CLASSES))
        xs[i] = make_image(cls, rng)
        if label_noise > 0 and rng.uniform() < label_noise:
            ys[i] = int(rng.integers(0, NUM_CLASSES))
        else:
            ys[i] = cls
    return xs, ys


# Canonical split seeds/sizes used by train.py and aot.py — the rust side
# loads the .npy files these produce and must agree on the protocol.
SPLITS = {
    "train": dict(n=8192, seed=0xA11CE, label_noise=0.02),
    "calib": dict(n=1024, seed=0xB0B, label_noise=0.0),
    "val": dict(n=1024, seed=0xC0FFEE, label_noise=0.0),
    "test": dict(n=1024, seed=0xD00D, label_noise=0.0),
}


def generate_split(name: str):
    cfg = SPLITS[name]
    return make_split(cfg["n"], cfg["seed"], cfg["label_noise"])
