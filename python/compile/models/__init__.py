"""L2 model zoo: scaled MobileNetV3-Small and ResNet-18 (see DESIGN.md
§Substitutions — faithful topologies, widths reduced for the 32x32
synthetic workload and the single-core CPU-PJRT execution environment)."""

from . import mobilenetv3, resnet18

REGISTRY = {
    "mobilenetv3": mobilenetv3,
    "resnet18": resnet18,
}


def get(name: str):
    return REGISTRY[name]
