"""MobileNetV3-Small (scaled for 32x32) — the paper's primary benchmark.

Faithful to Howard et al. (ICCV'19) §5 table 2 in structure: inverted
residual bottlenecks with depthwise convs, squeeze-excitation on selected
blocks, hard-swish in the deeper half, relu in the shallow half; widths and
block count reduced (~0.5x) and strides adapted from 224x224 to 32x32 so a
single CPU core can train and sweep it. The structures the paper's §V-C
analysis depends on — low-dimensional projection layers inside the
bottlenecks (predicted to prune the most), a shallow stem and a deep head
(predicted to prune the least) — are all present.
"""

from __future__ import annotations

import numpy as np

from ..layers import Net

NAME = "mobilenetv3"
NUM_CLASSES = 10
INPUT_HW = 32

# (kernel, expansion_ch, out_ch, use_se, activation, stride)
BLOCKS = [
    (3, 24, 16, True, "relu", 2),     # 32 -> 16
    (3, 56, 24, False, "relu", 2),    # 16 -> 8
    (3, 64, 24, False, "relu", 1),
    (5, 72, 32, True, "hswish", 2),   # 8 -> 4
    (5, 128, 32, True, "hswish", 1),
    (5, 96, 48, True, "hswish", 1),
]
STEM_CH = 16
HEAD_CH = 128
HIDDEN_CH = 160


def forward(net: Net, x):
    """Single traversal used by every mode (init/apply/trace/quant)."""
    t = net.input(x)

    t = net.conv("stem.conv", t, STEM_CH, 3, stride=1)
    t = net.bn("stem.bn", t)
    t = net.act("stem.act", t, "hswish")

    for i, (k, exp, out, use_se, act, stride) in enumerate(BLOCKS):
        p = f"block{i}"
        cin = int(t[0].shape[-1])
        residual = stride == 1 and cin == out
        t_in = t

        # expansion pointwise (GEMM hot spot on the INT8 path)
        t = net.conv(f"{p}.expand", t, exp, 1)
        t = net.bn(f"{p}.expand_bn", t)
        t = net.act(f"{p}.expand_act", t, act)
        # depthwise
        t = net.conv(f"{p}.dw", t, exp, k, stride=stride, groups=exp)
        t = net.bn(f"{p}.dw_bn", t)
        t = net.act(f"{p}.dw_act", t, act)
        if use_se:
            t = net.se(f"{p}.se", t)
        # linear low-dimensional projection (paper: prunes the most)
        t = net.conv(f"{p}.project", t, out, 1)
        t = net.bn(f"{p}.project_bn", t)
        if residual:
            t = net.add(f"{p}.add", t, t_in)

    t = net.conv("head.conv", t, HEAD_CH, 1)
    t = net.bn("head.bn", t)
    t = net.act("head.act", t, "hswish")
    t = net.gap("head.pool", t)
    t = net.fc("head.hidden", t, HIDDEN_CH)
    t = net.act("head.hidden_act", t, "hswish")
    t = net.fc("head.classifier", t, NUM_CLASSES, prunable=False)
    net.finalize()
    return t[0]


def init_params(seed: int = 0):
    rng = np.random.Generator(np.random.Philox(key=seed))
    net = Net("init", rng=rng)
    import jax.numpy as jnp

    forward(net, jnp.zeros((1, INPUT_HW, INPUT_HW, 3), jnp.float32))
    return net.params, net.param_order
