"""ResNet-18 (scaled for 32x32) — the paper's PTQ stress test (§V-D).

Faithful to He et al. (CVPR'16): 4 stages x 2 basic blocks, each block
conv3x3-bn-relu-conv3x3-bn + identity (or 1x1-projection when the shape
changes) and a final relu after the add. CIFAR-style stem (3x3/s1, no
maxpool) for 32x32 inputs; widths 0.25x ([8,16,32,64] vs [64,...,512]) so
the conditional-pruning loop's validation sweeps run in seconds on one CPU
core. The residual adds — the mechanism the paper blames for Q8-only's
constraint violation — are fully present, and the prune-group structure
reflects their coupling: each block's first conv (the "mid" channels) is
freely prunable, while trunk-channel producers are coupled through the adds
(rust/src/gopt liveness analysis handles removability).
"""

from __future__ import annotations

import numpy as np

from ..layers import Net

NAME = "resnet18"
NUM_CLASSES = 10
INPUT_HW = 32

STAGES = [8, 16, 32, 64]  # out channels per stage
BLOCKS_PER_STAGE = 2
STEM_CH = 8


def _basic_block(net: Net, t, p: str, cout: int, stride: int):
    cin = int(t[0].shape[-1])
    t_in = t
    t = net.conv(f"{p}.conv1", t, cout, 3, stride=stride)
    t = net.bn(f"{p}.bn1", t)
    t = net.act(f"{p}.act1", t, "relu")
    t = net.conv(f"{p}.conv2", t, cout, 3)
    t = net.bn(f"{p}.bn2", t)
    if stride != 1 or cin != cout:
        s = net.conv(f"{p}.down", t_in, cout, 1, stride=stride)
        s = net.bn(f"{p}.down_bn", s)
    else:
        s = t_in
    t = net.add(f"{p}.add", t, s)
    t = net.act(f"{p}.act2", t, "relu")
    return t


def forward(net: Net, x):
    t = net.input(x)
    t = net.conv("stem.conv", t, STEM_CH, 3)
    t = net.bn("stem.bn", t)
    t = net.act("stem.act", t, "relu")

    for s, cout in enumerate(STAGES):
        for b in range(BLOCKS_PER_STAGE):
            stride = 2 if (s > 0 and b == 0) else 1
            t = _basic_block(net, t, f"stage{s}.block{b}", cout, stride)

    t = net.gap("head.pool", t)
    t = net.fc("head.classifier", t, NUM_CLASSES, prunable=False)
    net.finalize()
    return t[0]


def init_params(seed: int = 1):
    rng = np.random.Generator(np.random.Philox(key=seed))
    net = Net("init", rng=rng)
    import jax.numpy as jnp

    forward(net, jnp.zeros((1, INPUT_HW, INPUT_HW, 3), jnp.float32))
    return net.params, net.param_order
