"""Build-time training of the two benchmark models on the synthetic dataset.

This replaces the paper's "pre-trained on ImageNet" starting point (see
DESIGN.md §Substitutions): HQP itself never trains — it only needs a trained
M_train with a measurable baseline accuracy. SGD + Nesterov momentum, cosine
LR, BatchNorm batch statistics during training with EMA running stats folded
into the exported parameter list.

Run once by `make artifacts` (aot.py calls train_model); ~5-10 min total on
the single CPU core of this environment. `--fast` trains a throwaway model
in ~30 s for CI smoke tests.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen
from . import model as M
from . import models as model_zoo

EMA = 0.9  # BN running-stat decay per step


def _split_params(params: dict):
    stats = {n: v for n, v in params.items() if n.endswith(".mean") or n.endswith(".var")}
    trainable = {n: v for n, v in params.items() if n not in stats}
    return trainable, stats


def train_model(
    name: str,
    epochs: int = 5,
    batch: int = 128,
    lr: float = 0.08,
    momentum: float = 0.9,
    seed: int = 0,
    log=print,
):
    """Train `name` on the synthetic train split; returns (params, history).

    The returned params dict contains the EMA-folded BN running stats, i.e.
    it is exactly the flat parameter set the AOT artifacts expect.
    """
    mod = model_zoo.get(name)
    params, order = mod.init_params(seed=seed)
    trainable, stats = _split_params(params)

    xs, ys = datagen.generate_split("train")
    n = xs.shape[0]
    steps_per_epoch = n // batch
    total_steps = epochs * steps_per_epoch

    loss_fn = M.make_train_loss(name, order)

    def step_fn(trainable, stats, velocity, x, y, lr_t):
        (loss, bn_stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            trainable, stats, x, y
        )
        new_vel = jax.tree_util.tree_map(
            lambda v, g: momentum * v + g, velocity, grads
        )
        new_tr = jax.tree_util.tree_map(
            lambda p, v, g: p - lr_t * (momentum * v + g), trainable, new_vel, grads
        )  # Nesterov
        new_stats = dict(stats)
        for bn_name, (bm, bv) in bn_stats.items():
            new_stats[bn_name + ".mean"] = EMA * stats[bn_name + ".mean"] + (1 - EMA) * bm
            new_stats[bn_name + ".var"] = EMA * stats[bn_name + ".var"] + (1 - EMA) * bv
        return new_tr, new_stats, new_vel, loss

    step_jit = jax.jit(step_fn)
    velocity = jax.tree_util.tree_map(jnp.zeros_like, trainable)

    rng = np.random.Generator(np.random.Philox(key=seed + 77))
    history = []
    t0 = time.time()
    step = 0
    for ep in range(epochs):
        perm = rng.permutation(n)
        ep_loss = 0.0
        for i in range(steps_per_epoch):
            idx = perm[i * batch : (i + 1) * batch]
            lr_t = 0.5 * lr * (1 + np.cos(np.pi * step / total_steps))
            trainable, stats, velocity, loss = step_jit(
                trainable, stats, velocity,
                jnp.asarray(xs[idx]), jnp.asarray(ys[idx]), jnp.float32(lr_t),
            )
            ep_loss += float(loss)
            step += 1
        acc = evaluate(name, order, {**trainable, **stats}, split="val")
        history.append(dict(epoch=ep, loss=ep_loss / steps_per_epoch, val_acc=acc))
        log(f"[{name}] epoch {ep}: loss={ep_loss/steps_per_epoch:.4f} "
            f"val_acc={acc:.4f} ({time.time()-t0:.0f}s)")

    params = {**trainable, **stats}
    return params, order, history


def evaluate(name: str, order: list, params: dict, split: str = "val",
             batch: int = 256) -> float:
    """Top-1 accuracy on a datagen split, eval-mode BN."""
    xs, ys = datagen.generate_split(split)
    ev = jax.jit(M.make_eval_logits(name, order))
    plist = M.params_to_list(params, order)
    correct = 0
    for i in range(0, xs.shape[0] - batch + 1, batch):
        logits, = ev(plist, jnp.asarray(xs[i : i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(ys[i : i + batch])))
    n = (xs.shape[0] // batch) * batch
    return correct / n
